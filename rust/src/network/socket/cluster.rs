//! [`SocketCluster`]: the coordinator side of the socket transport.
//!
//! Connects to a roster of `psfit worker` addresses, ships each node its
//! shard + config over the wire, and then drives the exact consensus
//! protocol of the in-process clusters — Bcast z, Collect (x_i, u_i) —
//! except the bytes are real.  Peer loss degrades the roster instead of
//! aborting: a worker that errors, times out, or closes its connection is
//! declared dead, the round commits with the survivors (the solver weights
//! its averages by actual replies), and only losing *every* worker is an
//! error.
//!
//! Byte accounting: `Round` request/reply frames land in
//! `net_down_bytes` / `net_up_bytes` — the same entries the in-process
//! transports model — while handshakes, setup, and control queries (loss,
//! ledger, warm export, reseed) land in `net_resync_bytes`.  Every frame
//! put on a socket increments `wire_frames`.  Unlike the modeled ledgers,
//! these counts include the protocol's own framing overhead.

use std::time::Duration;

use crate::backend::BlockParams;
use crate::config::{BackendKind, Config, TransportKind};
use crate::data::Dataset;
use crate::metrics::{CoordinationStats, TransferLedger};
use crate::network::socket::wire::{self, Setup, WireCommand, WireShard};
use crate::network::socket::{connect, Endpoint, SocketStream};
use crate::network::{Cluster, NodeReply, WarmState};

/// Connection settings for a [`SocketCluster`], normally derived from
/// `platform.*` via [`SocketOptions::from_config`].
#[derive(Debug, Clone)]
pub struct SocketOptions {
    /// Worker addresses, one per node in roster order.
    pub workers: Vec<String>,
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// Read timeout per expected reply; `None` waits forever.
    pub read_timeout: Option<Duration>,
    /// Connect retries after the first attempt.
    pub connect_retries: u32,
}

impl SocketOptions {
    /// Derive the options a config's `platform` section implies.
    pub fn from_config(cfg: &Config) -> SocketOptions {
        SocketOptions {
            workers: cfg.platform.workers.clone(),
            connect_timeout: Duration::from_millis(cfg.platform.connect_timeout_ms.max(1)),
            read_timeout: match cfg.platform.read_timeout_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            connect_retries: cfg.platform.connect_retries,
        }
    }
}

/// One live worker connection.
struct Peer {
    stream: SocketStream,
    addr: String,
}

/// Coordinator-side cluster over `psfit worker` processes.
///
/// Implements [`Cluster`], so `admm::solve` drives it exactly like the
/// in-process transports; on the same seed and ISA the supports and
/// objectives match them bit-for-bit (all floats cross the wire via
/// `to_le_bytes`).
pub struct SocketCluster {
    /// Slot per roster position; `None` = declared dead.
    peers: Vec<Option<Peer>>,
    /// Total roster size, including degraded members.
    roster: usize,
    /// Outer round counter (echoed by workers in every `RoundReply`).
    round: u64,
    /// Wire-side ledger: bytes and frames this coordinator actually put
    /// on (or read off) its sockets.
    net: TransferLedger,
    /// Round/participation/death accounting, reported via
    /// [`Cluster::coordination`].
    stats: CoordinationStats,
    /// Reusable encode buffer for the per-round broadcast.
    scratch: Vec<u8>,
}

impl SocketCluster {
    /// Connect to the fleet named by `cfg.platform.workers` and ship each
    /// node its shard.  Fails (rather than degrades) when any worker is
    /// unreachable or rejects its setup — a run should not *start* on a
    /// partial roster.
    pub fn connect(ds: &Dataset, cfg: &Config) -> anyhow::Result<SocketCluster> {
        let opts = SocketOptions::from_config(cfg);
        SocketCluster::connect_with(ds, cfg, &opts)
    }

    /// [`SocketCluster::connect`] with explicit connection settings.
    pub fn connect_with(
        ds: &Dataset,
        cfg: &Config,
        opts: &SocketOptions,
    ) -> anyhow::Result<SocketCluster> {
        anyhow::ensure!(
            cfg.platform.backend == BackendKind::Native,
            "the socket transport runs workers on the native backend only"
        );
        let roster = ds.nodes();
        anyhow::ensure!(
            opts.workers.len() >= roster,
            "socket transport needs {roster} worker address(es), got {}",
            opts.workers.len()
        );
        // Worker-side config: identical solver math, but local transport
        // with an empty roster so a worker can never recursively dial the
        // fleet it belongs to.
        let mut wcfg = cfg.clone();
        wcfg.platform.transport = TransportKind::Local;
        wcfg.platform.workers.clear();
        let config_text = wcfg.to_json().to_string();

        let mut net = TransferLedger::default();
        let mut peers = Vec::with_capacity(roster);
        for (i, shard) in ds.shards.iter().take(roster).enumerate() {
            let addr = opts.workers[i].clone();
            let ep = Endpoint::parse(&addr);
            let mut stream = connect(&ep, opts.connect_timeout, opts.connect_retries)?;
            stream.set_read_timeout(opts.read_timeout)?;
            net.net_resync_bytes += wire::client_handshake(&mut stream)? as u64;
            // The storage policy is applied here, coordinator-side, so the
            // worker reconstructs exactly the dense/CSR layout the
            // in-process transports would have used.
            let shard =
                shard.with_storage_policy(cfg.platform.sparse, cfg.platform.sparse_threshold);
            let setup = Setup {
                node: i as u32,
                nodes: roster as u32,
                n_features: ds.n_features as u32,
                width: ds.width as u32,
                direct_mode: false,
                config: config_text.clone(),
                shard: WireShard::from_shard(&shard),
            };
            let sent = wire::write_frame(&mut stream, &WireCommand::Setup(Box::new(setup)))?;
            net.net_resync_bytes += sent as u64;
            net.wire_frames += 1;
            match wire::read_frame(&mut stream)? {
                Some((WireCommand::SetupOk { node }, got)) if node as usize == i => {
                    net.net_resync_bytes += got as u64;
                    net.wire_frames += 1;
                }
                Some((WireCommand::Error { message }, _)) => {
                    anyhow::bail!("worker {addr} rejected setup for node {i}: {message}")
                }
                Some((other, _)) => {
                    anyhow::bail!("worker {addr}: unexpected `{}` to setup", other.name())
                }
                None => anyhow::bail!("worker {addr} closed the connection during setup"),
            }
            peers.push(Some(Peer { stream, addr }));
        }
        Ok(SocketCluster {
            peers,
            roster,
            round: 0,
            net,
            stats: CoordinationStats::new(roster),
            scratch: Vec::new(),
        })
    }

    /// Peers still connected.
    pub fn live(&self) -> usize {
        self.peers.iter().flatten().count()
    }

    /// Declare a peer dead: drop its connection, log, count the death.
    fn kill(&mut self, node: usize, why: &str) {
        if let Some(peer) = self.peers[node].take() {
            eprintln!("[socket] node {node} ({}) lost: {why}; degrading", peer.addr);
            self.stats.deaths += 1;
        }
    }
}

/// One request/reply control exchange with a peer, bytes ledgered as
/// resync traffic.  An `Error` reply, a clean close, or any wire error
/// becomes `Err` — callers kill the peer on that.
fn query(
    peer: &mut Peer,
    cmd: &WireCommand,
    net: &mut TransferLedger,
) -> anyhow::Result<WireCommand> {
    let sent = wire::write_frame(&mut peer.stream, cmd)?;
    net.net_resync_bytes += sent as u64;
    net.wire_frames += 1;
    match wire::read_frame(&mut peer.stream)? {
        Some((WireCommand::Error { message }, _)) => anyhow::bail!("{message}"),
        Some((reply, got)) => {
            net.net_resync_bytes += got as u64;
            net.wire_frames += 1;
            Ok(reply)
        }
        None => anyhow::bail!("connection closed mid-query"),
    }
}

impl Cluster for SocketCluster {
    fn nodes(&self) -> usize {
        self.roster
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        self.round += 1;
        let round = self.round;
        // encode once, write the same bytes to every live peer
        let mut payload = std::mem::take(&mut self.scratch);
        wire::encode_round_payload(round, z, &mut payload);
        let mut sent = vec![false; self.peers.len()];
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match wire::write_payload(&mut peer.stream, &payload) {
                    Ok(n) => {
                        self.net.net_down_bytes += n as u64;
                        self.net.wire_frames += 1;
                        sent[i] = true;
                    }
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        self.scratch = payload;
        // collect replies from everyone the broadcast reached
        let mut replies = Vec::new();
        for i in 0..self.peers.len() {
            if !sent[i] {
                continue;
            }
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match wire::read_frame(&mut peer.stream) {
                    Ok(Some((WireCommand::RoundReply { node, round: r, x, u }, got)))
                        if node as usize == i && r == round =>
                    {
                        self.net.net_up_bytes += got as u64;
                        self.net.wire_frames += 1;
                        self.stats.record_fold(i, 0);
                        replies.push(NodeReply {
                            node: i,
                            round: round as usize,
                            lag: 0,
                            x,
                            u,
                        });
                    }
                    Ok(Some((WireCommand::Error { message }, _))) => fail = Some(message),
                    Ok(Some((other, _))) => {
                        fail = Some(format!("unexpected `{}` to round {round}", other.name()))
                    }
                    Ok(None) => fail = Some(format!("connection closed during round {round}")),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        self.stats.rounds += 1;
        anyhow::ensure!(!replies.is_empty(), "round {round}: every socket worker is gone");
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        let mut total = 0.0;
        let mut got = 0usize;
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &WireCommand::Loss, &mut self.net) {
                    Ok(WireCommand::LossReply { value }) => {
                        total += value;
                        got += 1;
                    }
                    Ok(other) => fail = Some(format!("unexpected `{}` to loss", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        anyhow::ensure!(got > 0, "loss: every socket worker is gone");
        Ok(total)
    }

    fn ledger(&mut self) -> TransferLedger {
        let mut worker_side = Vec::new();
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &WireCommand::Ledger, &mut self.net) {
                    Ok(WireCommand::LedgerReply(l)) => worker_side.push(*l),
                    Ok(other) => fail = Some(format!("unexpected `{}` to ledger", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        // clone *after* the queries so their own bytes are included
        let mut out = self.net.clone();
        for l in &worker_side {
            out.merge(l);
        }
        out
    }

    fn coordination(&self) -> Option<CoordinationStats> {
        Some(self.stats.clone())
    }

    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        let mut states = Vec::new();
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &WireCommand::Export, &mut self.net) {
                    Ok(WireCommand::WarmReply(ws)) => states.push(*ws),
                    Ok(other) => fail = Some(format!("unexpected `{}` to export", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        anyhow::ensure!(!states.is_empty(), "warm export: every socket worker is gone");
        states.sort_by_key(|s| s.node);
        Ok(states)
    }

    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        let mut got = 0usize;
        for i in 0..self.peers.len() {
            if self.peers[i].is_none() {
                continue;
            }
            // each peer is shipped only its own state
            let Some(state) = states.iter().find(|s| s.node == i) else {
                anyhow::bail!("reseed: no warm state for node {i}");
            };
            let cmd = WireCommand::Reseed {
                rho_l: params.rho_l,
                rho_c: params.rho_c,
                reg: params.reg,
                states: vec![state.clone()],
            };
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &cmd, &mut self.net) {
                    Ok(WireCommand::ReseedOk { node }) if node as usize == i => got += 1,
                    Ok(other) => fail = Some(format!("unexpected `{}` to reseed", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        anyhow::ensure!(got > 0, "reseed: every socket worker is gone");
        Ok(())
    }
}

impl Drop for SocketCluster {
    fn drop(&mut self) {
        // best-effort clean close so worker sessions exit without noise
        for peer in self.peers.iter_mut().flatten() {
            let _ = wire::write_frame(&mut peer.stream, &WireCommand::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn connect_rejects_bad_rosters_before_dialing() {
        let ds = SyntheticSpec::regression(40, 120, 2).generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.platform.transport = TransportKind::Socket;
        // too few addresses
        cfg.platform.workers = vec!["127.0.0.1:1".into()];
        let err = SocketCluster::connect(&ds, &cfg).unwrap_err().to_string();
        assert!(err.contains("worker address(es)"), "{err}");
        // wrong backend
        cfg.platform.workers = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        cfg.platform.backend = BackendKind::Xla;
        let err = SocketCluster::connect(&ds, &cfg).unwrap_err().to_string();
        assert!(err.contains("native backend"), "{err}");
    }

    #[test]
    fn options_follow_the_config() {
        let mut cfg = Config::default();
        cfg.platform.connect_timeout_ms = 250;
        cfg.platform.read_timeout_ms = 0;
        cfg.platform.connect_retries = 7;
        let opts = SocketOptions::from_config(&cfg);
        assert_eq!(opts.connect_timeout, Duration::from_millis(250));
        assert_eq!(opts.read_timeout, None);
        assert_eq!(opts.connect_retries, 7);
    }
}

//! [`SocketCluster`]: the coordinator side of the socket transport.
//!
//! Connects to a roster of `psfit worker` addresses, ships each node its
//! shard + config over the wire, and then drives the exact consensus
//! protocol of the in-process clusters — Bcast z, Collect (x_i, u_i) —
//! except the bytes are real.  Peer loss degrades the roster instead of
//! aborting: a worker that errors, times out, or closes its connection is
//! declared dead, the round commits with the survivors (the solver weights
//! its averages by actual replies), and only dropping below the
//! configured quorum (by default, losing *every* worker) is an error.
//!
//! With `platform.rejoin` on, degradation becomes self-healing: dead
//! slots keep their address and setup envelope, get probed between
//! rounds on a capped-exponential, seeded-jitter backoff
//! ([`crate::util::backoff`]), and on answer are re-admitted with a
//! fresh `Setup` plus — when a prior export cached one — a warm-state
//! `Reseed`.  Rejoins and warm resyncs tick
//! [`CoordinationStats::rejoins`]/`resyncs`, and all recovery traffic is
//! ledgered as resync bytes.
//!
//! Byte accounting: `Round` request/reply frames land in
//! `net_down_bytes` / `net_up_bytes` — the same entries the in-process
//! transports model — while handshakes, setup, and control queries (loss,
//! ledger, warm export, reseed) land in `net_resync_bytes`.  Every frame
//! put on a socket increments `wire_frames`.  Unlike the modeled ledgers,
//! these counts include the protocol's own framing overhead.

use std::time::{Duration, Instant};

use crate::backend::BlockParams;
use crate::config::{BackendKind, Config, TransportKind};
use crate::data::Dataset;
use crate::metrics::{CoordinationStats, TransferLedger};
use crate::network::socket::wire::{self, Setup, WireCommand, WireShard};
use crate::network::socket::{connect, connect_backoff_seed, Endpoint, SocketStream};
use crate::network::{Cluster, NodeReply, WarmState};
use crate::util::backoff::Backoff;

/// Connection settings for a [`SocketCluster`], normally derived from
/// `platform.*` via [`SocketOptions::from_config`].
#[derive(Debug, Clone)]
pub struct SocketOptions {
    /// Worker addresses, one per node in roster order.
    pub workers: Vec<String>,
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// Read timeout per expected reply; `None` waits forever.
    pub read_timeout: Option<Duration>,
    /// Connect retries after the first attempt.
    pub connect_retries: u32,
    /// Keep dead peers' addresses and probe them between rounds
    /// (self-healing); off by default so degradation semantics and byte
    /// ledgers stay exactly as configured runs expect.
    pub rejoin: bool,
    /// Minimum replies a round may commit with before the run fails
    /// (`0` = any survivor, the pre-quorum behavior).
    pub quorum: usize,
}

impl SocketOptions {
    /// Derive the options a config's `platform` section implies.
    pub fn from_config(cfg: &Config) -> SocketOptions {
        SocketOptions {
            workers: cfg.platform.workers.clone(),
            connect_timeout: Duration::from_millis(cfg.platform.connect_timeout_ms.max(1)),
            read_timeout: match cfg.platform.read_timeout_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            connect_retries: cfg.platform.connect_retries,
            rejoin: cfg.platform.rejoin,
            quorum: cfg.platform.quorum as usize,
        }
    }
}

/// One live worker connection.
struct Peer {
    stream: SocketStream,
    addr: String,
}

/// Reconnect state for one roster slot: where its worker lives and when
/// the next probe is due.
struct HealSlot {
    /// The slot's worker address (kept even while the peer is dead).
    addr: String,
    /// Capped-exponential probe schedule, seeded per address.
    backoff: Backoff,
    /// Probes before this instant are skipped — dead slots cost a round
    /// nothing until their backoff expires.
    next_probe: Instant,
}

/// The self-healing layer: everything a [`SocketCluster`] needs to
/// re-admit a dead peer mid-solve.  Built only when `platform.rejoin` is
/// on (the retained [`Setup`] envelopes hold a copy of every shard).
struct Heal {
    /// Per-roster-slot reconnect state.
    slots: Vec<HealSlot>,
    /// The exact setup envelope each slot received at connect time — a
    /// rejoining worker rebuilds its node from this, bit-identically.
    setups: Vec<Setup>,
    /// Last exported warm state per node (refreshed by every
    /// `export_warm`/`reseed`, e.g. each fit-checkpoint write); a rejoin
    /// with a cached state resyncs warm, otherwise the node cold-starts.
    warm: Vec<Option<WarmState>>,
    /// Block penalties shipped with a rejoin's warm `Reseed`.
    params: BlockParams,
    /// Per-probe connect timeout (one attempt per due slot per round).
    timeout: Duration,
    /// Read timeout applied to a re-admitted connection.
    read_timeout: Option<Duration>,
}

/// Coordinator-side cluster over `psfit worker` processes.
///
/// Implements [`Cluster`], so `admm::solve` drives it exactly like the
/// in-process transports; on the same seed and ISA the supports and
/// objectives match them bit-for-bit (all floats cross the wire via
/// `to_le_bytes`).
pub struct SocketCluster {
    /// Slot per roster position; `None` = declared dead.
    peers: Vec<Option<Peer>>,
    /// Total roster size, including degraded members.
    roster: usize,
    /// Outer round counter (echoed by workers in every `RoundReply`).
    round: u64,
    /// Wire-side ledger: bytes and frames this coordinator actually put
    /// on (or read off) its sockets.
    net: TransferLedger,
    /// Round/participation/death accounting, reported via
    /// [`Cluster::coordination`].
    stats: CoordinationStats,
    /// Reusable encode buffer for the per-round broadcast.
    scratch: Vec<u8>,
    /// Minimum replies a round may commit with (`0` behaves as `1`).
    quorum: usize,
    /// The most recent peer-loss reason, surfaced in quorum-failure
    /// errors so a failed serve job reports *why* its fleet shrank.
    last_error: String,
    /// Self-healing state; `None` when `platform.rejoin` is off.
    heal: Option<Heal>,
}

impl SocketCluster {
    /// Connect to the fleet named by `cfg.platform.workers` and ship each
    /// node its shard.  Fails (rather than degrades) when any worker is
    /// unreachable or rejects its setup — a run should not *start* on a
    /// partial roster.
    pub fn connect(ds: &Dataset, cfg: &Config) -> anyhow::Result<SocketCluster> {
        let opts = SocketOptions::from_config(cfg);
        SocketCluster::connect_with(ds, cfg, &opts)
    }

    /// [`SocketCluster::connect`] with explicit connection settings.
    pub fn connect_with(
        ds: &Dataset,
        cfg: &Config,
        opts: &SocketOptions,
    ) -> anyhow::Result<SocketCluster> {
        anyhow::ensure!(
            cfg.platform.backend == BackendKind::Native,
            "the socket transport runs workers on the native backend only"
        );
        let roster = ds.nodes();
        anyhow::ensure!(
            opts.workers.len() >= roster,
            "socket transport needs {roster} worker address(es), got {}",
            opts.workers.len()
        );
        // Worker-side config: identical solver math, but local transport
        // with an empty roster so a worker can never recursively dial the
        // fleet it belongs to.
        let mut wcfg = cfg.clone();
        wcfg.platform.transport = TransportKind::Local;
        wcfg.platform.workers.clear();
        let config_text = wcfg.to_json().to_string();

        anyhow::ensure!(
            opts.quorum <= roster,
            "quorum {} exceeds the {roster}-node roster",
            opts.quorum
        );
        let mut net = TransferLedger::default();
        let mut peers = Vec::with_capacity(roster);
        let mut setups = Vec::new();
        for (i, shard) in ds.shards.iter().take(roster).enumerate() {
            let addr = opts.workers[i].clone();
            let ep = Endpoint::parse(&addr);
            let mut stream = connect(&ep, opts.connect_timeout, opts.connect_retries)?;
            stream.set_read_timeout(opts.read_timeout)?;
            net.net_resync_bytes += wire::client_handshake(&mut stream)? as u64;
            // The storage policy is applied here, coordinator-side, so the
            // worker reconstructs exactly the dense/CSR layout the
            // in-process transports would have used.
            let shard =
                shard.with_storage_policy(cfg.platform.sparse, cfg.platform.sparse_threshold);
            let setup = Setup {
                node: i as u32,
                nodes: roster as u32,
                n_features: ds.n_features as u32,
                width: ds.width as u32,
                direct_mode: false,
                config: config_text.clone(),
                shard: WireShard::from_shard(&shard),
            };
            if opts.rejoin {
                // the rejoin path re-ships exactly this envelope later
                setups.push(setup.clone());
            }
            let sent = wire::write_frame(&mut stream, &WireCommand::Setup(Box::new(setup)))?;
            net.net_resync_bytes += sent as u64;
            net.wire_frames += 1;
            match wire::read_frame(&mut stream)? {
                Some((WireCommand::SetupOk { node }, got)) if node as usize == i => {
                    net.net_resync_bytes += got as u64;
                    net.wire_frames += 1;
                }
                Some((WireCommand::Error { message }, _)) => {
                    anyhow::bail!("worker {addr} rejected setup for node {i}: {message}")
                }
                Some((other, _)) => {
                    anyhow::bail!("worker {addr}: unexpected `{}` to setup", other.name())
                }
                None => anyhow::bail!("worker {addr} closed the connection during setup"),
            }
            peers.push(Some(Peer { stream, addr }));
        }
        let heal = opts.rejoin.then(|| Heal {
            slots: opts
                .workers
                .iter()
                .take(roster)
                .map(|addr| HealSlot {
                    addr: addr.clone(),
                    backoff: Backoff::new(
                        Duration::from_millis(50),
                        Duration::from_millis(2000),
                        connect_backoff_seed(&Endpoint::parse(addr)),
                    ),
                    next_probe: Instant::now(),
                })
                .collect(),
            setups,
            warm: vec![None; roster],
            params: BlockParams {
                rho_l: cfg.solver.rho_l,
                rho_c: cfg.solver.rho_c,
                reg: cfg.solver.block_reg(roster),
            },
            timeout: opts.connect_timeout,
            read_timeout: opts.read_timeout,
        });
        Ok(SocketCluster {
            peers,
            roster,
            round: 0,
            net,
            stats: CoordinationStats::new(roster),
            scratch: Vec::new(),
            quorum: opts.quorum,
            last_error: String::new(),
            heal,
        })
    }

    /// Peers still connected.
    pub fn live(&self) -> usize {
        self.peers.iter().flatten().count()
    }

    /// Declare a peer dead: drop its connection, log, count the death.
    /// With self-healing on, the slot's rejoin probes start immediately
    /// (the first probe fires before the next round).
    fn kill(&mut self, node: usize, why: &str) {
        if let Some(peer) = self.peers[node].take() {
            eprintln!("[socket] node {node} ({}) lost: {why}; degrading", peer.addr);
            self.stats.deaths += 1;
            self.last_error = format!("node {node}: {why}");
            if let Some(heal) = self.heal.as_mut() {
                heal.slots[node].backoff.reset();
                heal.slots[node].next_probe = Instant::now();
            }
        }
    }

    /// The most recent peer-loss reason, for error reporting.
    fn last_error_or_none(&self) -> &str {
        if self.last_error.is_empty() {
            "none"
        } else {
            &self.last_error
        }
    }

    /// Probe every dead slot whose backoff has expired and re-admit the
    /// ones that answer: fresh `Setup` (bit-identical to the original),
    /// then a warm `Reseed` when a cached export exists.  All traffic is
    /// ledgered as resync bytes; each success ticks `rejoins` (and
    /// `resyncs` when warm state was restored).  Called between rounds,
    /// so a healing fleet never blocks a committed round.
    fn try_rejoin(&mut self) {
        let Some(heal) = self.heal.as_mut() else {
            return;
        };
        for i in 0..self.peers.len() {
            if self.peers[i].is_some() {
                continue;
            }
            let slot = &mut heal.slots[i];
            if Instant::now() < slot.next_probe {
                continue;
            }
            let warm = heal.warm[i].as_ref();
            match redial(
                slot,
                &heal.setups[i],
                warm,
                heal.params,
                heal.timeout,
                heal.read_timeout,
                &mut self.net,
            ) {
                Ok(peer) => {
                    eprintln!(
                        "[socket] node {i} ({}) rejoined after {} probe(s) ({})",
                        slot.addr,
                        slot.backoff.attempts() + 1,
                        if warm.is_some() {
                            "warm resync"
                        } else {
                            "cold restart"
                        }
                    );
                    slot.backoff.reset();
                    self.peers[i] = Some(peer);
                    self.stats.rejoins += 1;
                    if warm.is_some() {
                        self.stats.resyncs += 1;
                    }
                }
                Err(_) => {
                    // probes fail routinely while the worker is down;
                    // stay quiet and wait out the (growing) backoff
                    slot.next_probe = Instant::now() + slot.backoff.next_delay();
                }
            }
        }
    }
}

/// One rejoin attempt against a dead slot's address: dial, handshake,
/// re-ship the original `Setup`, and — when a cached warm state exists —
/// restore it with a `Reseed`.  Every byte lands in `net_resync_bytes`.
fn redial(
    slot: &HealSlot,
    setup: &Setup,
    warm: Option<&WarmState>,
    params: BlockParams,
    timeout: Duration,
    read_timeout: Option<Duration>,
    net: &mut TransferLedger,
) -> anyhow::Result<Peer> {
    let node = setup.node as usize;
    // single attempt per probe: the between-probe pacing is the slot's
    // backoff, not connect()'s retry loop
    let mut stream = connect(&Endpoint::parse(&slot.addr), timeout, 0)?;
    stream.set_read_timeout(read_timeout)?;
    net.net_resync_bytes += wire::client_handshake(&mut stream)? as u64;
    let sent = wire::write_frame(&mut stream, &WireCommand::Setup(Box::new(setup.clone())))?;
    net.net_resync_bytes += sent as u64;
    net.wire_frames += 1;
    match wire::read_frame(&mut stream)? {
        Some((WireCommand::SetupOk { node: got }, bytes)) if got as usize == node => {
            net.net_resync_bytes += bytes as u64;
            net.wire_frames += 1;
        }
        Some((WireCommand::Error { message }, _)) => {
            anyhow::bail!("rejoin setup rejected: {message}")
        }
        Some((other, _)) => anyhow::bail!("unexpected `{}` to rejoin setup", other.name()),
        None => anyhow::bail!("connection closed during rejoin setup"),
    }
    let mut peer = Peer {
        stream,
        addr: slot.addr.clone(),
    };
    if let Some(state) = warm {
        let cmd = WireCommand::Reseed {
            rho_l: params.rho_l,
            rho_c: params.rho_c,
            reg: params.reg,
            states: vec![state.clone()],
        };
        match query(&mut peer, &cmd, net)? {
            WireCommand::ReseedOk { node: got } if got as usize == node => {}
            other => anyhow::bail!("unexpected `{}` to rejoin reseed", other.name()),
        }
    }
    Ok(peer)
}

/// One request/reply control exchange with a peer, bytes ledgered as
/// resync traffic.  An `Error` reply, a clean close, or any wire error
/// becomes `Err` — callers kill the peer on that.
fn query(
    peer: &mut Peer,
    cmd: &WireCommand,
    net: &mut TransferLedger,
) -> anyhow::Result<WireCommand> {
    let sent = wire::write_frame(&mut peer.stream, cmd)?;
    net.net_resync_bytes += sent as u64;
    net.wire_frames += 1;
    match wire::read_frame(&mut peer.stream)? {
        Some((WireCommand::Error { message }, _)) => anyhow::bail!("{message}"),
        Some((reply, got)) => {
            net.net_resync_bytes += got as u64;
            net.wire_frames += 1;
            Ok(reply)
        }
        None => anyhow::bail!("connection closed mid-query"),
    }
}

impl Cluster for SocketCluster {
    fn nodes(&self) -> usize {
        self.roster
    }

    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        // heal before broadcasting, so a recovered worker participates in
        // this very round
        self.try_rejoin();
        self.round += 1;
        let round = self.round;
        // encode once, write the same bytes to every live peer
        let mut payload = std::mem::take(&mut self.scratch);
        wire::encode_round_payload(round, z, &mut payload);
        let mut sent = vec![false; self.peers.len()];
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match wire::write_payload(&mut peer.stream, &payload) {
                    Ok(n) => {
                        self.net.net_down_bytes += n as u64;
                        self.net.wire_frames += 1;
                        sent[i] = true;
                    }
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        self.scratch = payload;
        // collect replies from everyone the broadcast reached
        let mut replies = Vec::new();
        for i in 0..self.peers.len() {
            if !sent[i] {
                continue;
            }
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match wire::read_frame(&mut peer.stream) {
                    Ok(Some((WireCommand::RoundReply { node, round: r, x, u }, got)))
                        if node as usize == i && r == round =>
                    {
                        self.net.net_up_bytes += got as u64;
                        self.net.wire_frames += 1;
                        self.stats.record_fold(i, 0);
                        replies.push(NodeReply {
                            node: i,
                            round: round as usize,
                            lag: 0,
                            x,
                            u,
                        });
                    }
                    Ok(Some((WireCommand::Error { message }, _))) => fail = Some(message),
                    Ok(Some((other, _))) => {
                        fail = Some(format!("unexpected `{}` to round {round}", other.name()))
                    }
                    Ok(None) => fail = Some(format!("connection closed during round {round}")),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        self.stats.rounds += 1;
        if replies.is_empty() {
            anyhow::bail!(
                "round {round}: every socket worker is gone ({} death(s), last error: {})",
                self.stats.deaths,
                self.last_error_or_none()
            );
        }
        let need = self.quorum.max(1);
        if replies.len() < need {
            anyhow::bail!(
                "round {round}: quorum lost — {} of {} worker(s) replied, need {need} \
                 ({} death(s), last error: {})",
                replies.len(),
                self.roster,
                self.stats.deaths,
                self.last_error_or_none()
            );
        }
        Ok(replies)
    }

    fn loss_value(&mut self) -> anyhow::Result<f64> {
        let mut total = 0.0;
        let mut got = 0usize;
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &WireCommand::Loss, &mut self.net) {
                    Ok(WireCommand::LossReply { value }) => {
                        total += value;
                        got += 1;
                    }
                    Ok(other) => fail = Some(format!("unexpected `{}` to loss", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        anyhow::ensure!(got > 0, "loss: every socket worker is gone");
        Ok(total)
    }

    fn ledger(&mut self) -> TransferLedger {
        let mut worker_side = Vec::new();
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &WireCommand::Ledger, &mut self.net) {
                    Ok(WireCommand::LedgerReply(l)) => worker_side.push(*l),
                    Ok(other) => fail = Some(format!("unexpected `{}` to ledger", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        // clone *after* the queries so their own bytes are included
        let mut out = self.net.clone();
        for l in &worker_side {
            out.merge(l);
        }
        out
    }

    fn coordination(&self) -> Option<CoordinationStats> {
        Some(self.stats.clone())
    }

    fn fast_forward(&mut self, round: usize) {
        // the wire counter is 1-based and pre-incremented: after `round`
        // completed rounds the counter reads `round`, so the next frame
        // carries `round + 1` and workers index its chunk as `round`
        self.round = round as u64;
    }

    fn banish(&mut self, node: usize, why: &str) {
        // a structured death like any other peer loss: the slot degrades,
        // and with self-healing on the worker may rejoin (fresh state,
        // clean duals) once its rejoin probe answers
        if node < self.peers.len() {
            self.kill(node, why);
        }
    }

    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        let mut states = Vec::new();
        for i in 0..self.peers.len() {
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &WireCommand::Export, &mut self.net) {
                    Ok(WireCommand::WarmReply(ws)) => states.push(*ws),
                    Ok(other) => fail = Some(format!("unexpected `{}` to export", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        anyhow::ensure!(!states.is_empty(), "warm export: every socket worker is gone");
        states.sort_by_key(|s| s.node);
        if let Some(heal) = self.heal.as_mut() {
            // every export refreshes the rejoin cache — with periodic fit
            // checkpoints this keeps warm resyncs at most one checkpoint
            // interval stale
            for s in &states {
                if s.node < heal.warm.len() {
                    heal.warm[s.node] = Some(s.clone());
                }
            }
        }
        Ok(states)
    }

    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        let mut got = 0usize;
        for i in 0..self.peers.len() {
            if self.peers[i].is_none() {
                continue;
            }
            // each peer is shipped only its own state
            let Some(state) = states.iter().find(|s| s.node == i) else {
                anyhow::bail!("reseed: no warm state for node {i}");
            };
            let cmd = WireCommand::Reseed {
                rho_l: params.rho_l,
                rho_c: params.rho_c,
                reg: params.reg,
                states: vec![state.clone()],
            };
            let mut fail = None;
            if let Some(peer) = self.peers[i].as_mut() {
                match query(peer, &cmd, &mut self.net) {
                    Ok(WireCommand::ReseedOk { node }) if node as usize == i => got += 1,
                    Ok(other) => fail = Some(format!("unexpected `{}` to reseed", other.name())),
                    Err(e) => fail = Some(e.to_string()),
                }
            }
            if let Some(msg) = fail {
                self.kill(i, &msg);
            }
        }
        anyhow::ensure!(got > 0, "reseed: every socket worker is gone");
        if let Some(heal) = self.heal.as_mut() {
            // a reseed defines each node's state at least as authoritatively
            // as an export: cache it for future rejoins
            for s in states {
                if s.node < heal.warm.len() {
                    heal.warm[s.node] = Some(s.clone());
                }
            }
        }
        Ok(())
    }
}

impl Drop for SocketCluster {
    fn drop(&mut self) {
        // best-effort clean close so worker sessions exit without noise
        for peer in self.peers.iter_mut().flatten() {
            let _ = wire::write_frame(&mut peer.stream, &WireCommand::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;

    #[test]
    fn connect_rejects_bad_rosters_before_dialing() {
        let ds = SyntheticSpec::regression(40, 120, 2).generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.platform.transport = TransportKind::Socket;
        // too few addresses
        cfg.platform.workers = vec!["127.0.0.1:1".into()];
        let err = SocketCluster::connect(&ds, &cfg).unwrap_err().to_string();
        assert!(err.contains("worker address(es)"), "{err}");
        // wrong backend
        cfg.platform.workers = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        cfg.platform.backend = BackendKind::Xla;
        let err = SocketCluster::connect(&ds, &cfg).unwrap_err().to_string();
        assert!(err.contains("native backend"), "{err}");
    }

    #[test]
    fn options_follow_the_config() {
        let mut cfg = Config::default();
        cfg.platform.connect_timeout_ms = 250;
        cfg.platform.read_timeout_ms = 0;
        cfg.platform.connect_retries = 7;
        cfg.platform.rejoin = true;
        cfg.platform.quorum = 2;
        let opts = SocketOptions::from_config(&cfg);
        assert_eq!(opts.connect_timeout, Duration::from_millis(250));
        assert_eq!(opts.read_timeout, None);
        assert_eq!(opts.connect_retries, 7);
        assert!(opts.rejoin);
        assert_eq!(opts.quorum, 2);
    }

    #[test]
    fn connect_rejects_an_unmeetable_quorum() {
        let ds = SyntheticSpec::regression(40, 120, 2).generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.platform.transport = TransportKind::Socket;
        cfg.platform.workers = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        cfg.platform.quorum = 3; // > roster: impossible before dialing
        let err = SocketCluster::connect(&ds, &cfg).unwrap_err().to_string();
        assert!(err.contains("quorum"), "{err}");
    }
}

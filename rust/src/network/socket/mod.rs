//! Socket transport: real multi-process clusters over TCP or Unix
//! domain sockets.
//!
//! This subsystem replaces the in-process "MPI stand-in" with actual
//! processes on an actual wire, while keeping the solver's view — the
//! [`crate::network::Cluster`] trait — unchanged:
//!
//! - [`wire`]: the versioned, length-prefixed, checksummed binary
//!   protocol every psfit socket speaks.
//! - [`cluster`]: [`SocketCluster`], the coordinator side — connects to a
//!   roster of worker addresses, ships each node its shard, and drives
//!   consensus rounds over the wire with peer-death degradation.
//! - [`worker`]: the `psfit worker` process — hosts one `NodeWorker` per
//!   connection on a `NativeBackend`, so a single worker process serves
//!   many concurrent jobs (the multiplexing `psfit serve` relies on).
//!
//! Addresses are `host:port` for TCP or `unix:/path/to.sock` for Unix
//! domain sockets.  All floats cross the wire via `to_le_bytes`, so a
//! localhost socket cluster reproduces the in-process transports'
//! supports and objectives bit-for-bit on the same seed (asserted in
//! `tests/socket.rs` and by the CI multi-process smoke job).

/// Deterministic fault-injection proxy (`psfit chaos`).
pub mod chaos;
pub mod cluster;
pub mod wire;
pub mod worker;

pub use chaos::{ChaosProxy, ChaosSpec};
pub use cluster::{SocketCluster, SocketOptions};
pub use wire::{JobSpec, JobStatus, JobSummary, WireCommand};
pub use worker::{run_worker, spawn_local_worker, WorkerOpts};

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::time::Duration;

use crate::util::backoff::Backoff;

/// A parsed socket address: TCP `host:port` or `unix:/path`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP endpoint in `host:port` form.
    Tcp(String),
    /// Unix-domain socket path.
    Unix(PathBuf),
}

impl Endpoint {
    /// Parse an address string; a `unix:` prefix selects a Unix-domain
    /// socket, anything else is treated as TCP `host:port`.
    pub fn parse(s: &str) -> Endpoint {
        match s.strip_prefix("unix:") {
            Some(path) => Endpoint::Unix(PathBuf::from(path)),
            None => Endpoint::Tcp(s.to_string()),
        }
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(addr) => f.write_str(addr),
            Endpoint::Unix(path) => write!(f, "unix:{}", path.display()),
        }
    }
}

/// A connected stream over either socket family.  TCP streams run with
/// `TCP_NODELAY` so the small per-round vectors are not Nagle-delayed.
#[derive(Debug)]
pub enum SocketStream {
    /// TCP connection.
    Tcp(TcpStream),
    /// Unix-domain connection.
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl SocketStream {
    /// Bound the blocking time of every subsequent read; `None` blocks
    /// forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        match self {
            SocketStream::Tcp(s) => s.set_read_timeout(timeout),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.set_read_timeout(timeout),
        }
    }

    /// A second handle onto the same underlying socket — the chaos proxy
    /// uses one handle per pump direction.
    pub fn try_clone(&self) -> std::io::Result<SocketStream> {
        match self {
            SocketStream::Tcp(s) => s.try_clone().map(SocketStream::Tcp),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.try_clone().map(SocketStream::Unix),
        }
    }

    /// Best-effort shutdown of both directions, so every clone of this
    /// socket — and the peer — sees the connection die immediately.
    pub fn shutdown(&self) {
        match self {
            SocketStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            SocketStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for SocketStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for SocketStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            SocketStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.flush(),
        }
    }
}

/// A bound listener over either socket family.
#[derive(Debug)]
pub enum SocketListener {
    /// TCP listener.
    Tcp(TcpListener),
    /// Unix-domain listener (the path is removed first so rebinding a
    /// stale socket file works).
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener, PathBuf),
}

impl SocketListener {
    /// Bind the endpoint.  TCP port `0` binds an ephemeral port; the
    /// actual address is reported by [`SocketListener::local_endpoint`].
    pub fn bind(ep: &Endpoint) -> anyhow::Result<SocketListener> {
        match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("cannot bind tcp {addr}: {e}"))?;
                Ok(SocketListener::Tcp(l))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = std::os::unix::net::UnixListener::bind(path).map_err(|e| {
                    anyhow::anyhow!("cannot bind unix socket {}: {e}", path.display())
                })?;
                Ok(SocketListener::Unix(l, path.clone()))
            }
            #[cfg(not(unix))]
            Endpoint::Unix(path) => {
                anyhow::bail!(
                    "unix-domain sockets are not supported on this platform ({})",
                    path.display()
                )
            }
        }
    }

    /// Accept one connection.
    pub fn accept(&self) -> std::io::Result<SocketStream> {
        match self {
            SocketListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(SocketStream::Tcp(s))
            }
            #[cfg(unix)]
            SocketListener::Unix(l, _) => {
                let (s, _) = l.accept()?;
                Ok(SocketStream::Unix(s))
            }
        }
    }

    /// The actually-bound address in the same syntax [`Endpoint::parse`]
    /// accepts (resolves TCP port `0` to the assigned port).
    pub fn local_endpoint(&self) -> String {
        match self {
            SocketListener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?:?".to_string()),
            #[cfg(unix)]
            SocketListener::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }
}

#[cfg(unix)]
impl Drop for SocketListener {
    fn drop(&mut self) {
        if let SocketListener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Connect to `ep` with a per-attempt timeout and bounded retry
/// (`retries` additional attempts after the first, sleeping through the
/// shared [`crate::util::backoff`] policy: capped exponential growth with
/// seeded jitter) — workers that are still binding their listener when
/// the coordinator starts are absorbed here instead of failing the run.
pub fn connect(ep: &Endpoint, timeout: Duration, retries: u32) -> anyhow::Result<SocketStream> {
    // seed from the address so two coordinators hammering the same dead
    // worker still fan their retries apart deterministically
    let mut backoff = Backoff::new(
        Duration::from_millis(50),
        Duration::from_millis(2000),
        connect_backoff_seed(ep),
    );
    let mut last_err = String::new();
    for attempt in 0..=retries {
        if attempt > 0 {
            crate::util::backoff::sleep_next(&mut backoff);
        }
        match connect_once(ep, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = e.to_string(),
        }
    }
    anyhow::bail!(
        "cannot connect to {ep} after {} attempt(s): {last_err}",
        retries + 1
    )
}

/// Deterministic per-address backoff seed (FNV-1a over the display form).
pub(crate) fn connect_backoff_seed(ep: &Endpoint) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in ep.to_string().bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn connect_once(ep: &Endpoint, timeout: Duration) -> anyhow::Result<SocketStream> {
    match ep {
        Endpoint::Tcp(addr) => {
            let mut resolved = addr
                .to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("cannot resolve {addr}: {e}"))?;
            let sock = resolved
                .next()
                .ok_or_else(|| anyhow::anyhow!("{addr} resolves to no address"))?;
            let s = TcpStream::connect_timeout(&sock, timeout)?;
            let _ = s.set_nodelay(true);
            Ok(SocketStream::Tcp(s))
        }
        #[cfg(unix)]
        Endpoint::Unix(path) => {
            let s = std::os::unix::net::UnixStream::connect(path)?;
            Ok(SocketStream::Unix(s))
        }
        #[cfg(not(unix))]
        Endpoint::Unix(path) => anyhow::bail!(
            "unix-domain sockets are not supported on this platform ({})",
            path.display()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parse_and_display() {
        assert_eq!(
            Endpoint::parse("127.0.0.1:7700"),
            Endpoint::Tcp("127.0.0.1:7700".into())
        );
        assert_eq!(
            Endpoint::parse("unix:/tmp/w.sock"),
            Endpoint::Unix(PathBuf::from("/tmp/w.sock"))
        );
        assert_eq!(Endpoint::parse("unix:/tmp/w.sock").to_string(), "unix:/tmp/w.sock");
        assert_eq!(Endpoint::parse("h:1").to_string(), "h:1");
    }

    #[test]
    fn tcp_listener_reports_ephemeral_port_and_talks() {
        let l = SocketListener::bind(&Endpoint::parse("127.0.0.1:0")).unwrap();
        let addr = l.local_endpoint();
        assert!(!addr.ends_with(":0"), "port 0 must resolve: {addr}");
        let t = std::thread::spawn(move || {
            let mut s = l.accept().unwrap();
            let mut b = [0u8; 2];
            s.read_exact(&mut b).unwrap();
            s.write_all(&b).unwrap();
        });
        let mut c = connect(&Endpoint::parse(&addr), Duration::from_secs(2), 2).unwrap();
        c.write_all(b"hi").unwrap();
        let mut back = [0u8; 2];
        c.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hi");
        t.join().unwrap();
    }

    #[test]
    fn connect_to_dead_port_fails_cleanly() {
        // bind-then-drop guarantees the port is closed
        let l = SocketListener::bind(&Endpoint::parse("127.0.0.1:0")).unwrap();
        let addr = l.local_endpoint();
        drop(l);
        let err = connect(&Endpoint::parse(&addr), Duration::from_millis(200), 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot connect"), "{err}");
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_roundtrip() {
        let path = std::env::temp_dir().join(format!("psfit-sock-test-{}", std::process::id()));
        let ep = Endpoint::Unix(path.clone());
        let l = SocketListener::bind(&ep).unwrap();
        let t = std::thread::spawn(move || {
            let mut s = l.accept().unwrap();
            let mut b = [0u8; 1];
            s.read_exact(&mut b).unwrap();
            s.write_all(&[b[0] + 1]).unwrap();
        });
        let mut c = connect(&ep, Duration::from_secs(2), 0).unwrap();
        c.write_all(&[41]).unwrap();
        let mut back = [0u8; 1];
        c.read_exact(&mut back).unwrap();
        assert_eq!(back[0], 42);
        t.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}

//! Versioned, length-prefixed binary wire protocol for the socket
//! transport and the `psfit serve` daemon.
//!
//! Every connection starts with an 8-byte handshake in each direction
//! (`b"PSFW"` magic + little-endian `u32` protocol version) so version
//! skew and port confusion fail with a clean error instead of a garbled
//! stream.  After the handshake, each message is one *frame*:
//!
//! ```text
//! | u32 payload_len (LE) | payload bytes | u64 FNV-1a(payload) (LE) |
//! ```
//!
//! The payload's first byte is the command tag; all integers are
//! little-endian and floats are IEEE-754 `to_le_bytes`, so `f64`/`f32`
//! values survive the wire bit-for-bit — the property behind the
//! socket-vs-in-process parity oracle.  [`read_frame`] distinguishes a
//! clean close (EOF exactly at a frame boundary → `Ok(None)`) from a
//! truncated stream, and every decode path is bounds-checked: truncated
//! frames, corrupted checksums, oversized lengths, and unknown tags all
//! surface as errors, never panics or hangs (reads respect the stream's
//! configured timeout).

use crate::data::{Shard, ShardData};
use crate::linalg::{CsrMatrix, Matrix};
use crate::metrics::TransferLedger;
use crate::network::WarmState;
use std::io::{Read, Write};
use std::sync::Arc;

/// Handshake magic: "PSfit Wire".
pub const MAGIC: &[u8; 4] = b"PSFW";
/// Wire protocol version; bumped on any frame-layout change.  v2 added
/// the `JobSummary` failure-detail string and the structured `Rejected`
/// reply a draining daemon answers `Submit` with.
pub const VERSION: u32 = 2;
/// Upper bound on a frame payload (1 GiB) — rejects absurd lengths from a
/// corrupted or hostile stream before any allocation happens.
pub const MAX_FRAME: usize = 1 << 30;
/// Per-frame overhead in bytes beyond the payload (length prefix +
/// checksum trailer).
pub const FRAME_OVERHEAD: usize = 4 + 8;
/// Bytes exchanged by a complete two-way handshake.
pub const HANDSHAKE_BYTES: usize = 16;

// Command tags.  Coordinator -> worker: 1..=7; worker -> coordinator:
// 16..=22; serve client -> daemon: 32..=35; daemon -> client: 48..=52.
const TAG_SETUP: u8 = 1;
const TAG_ROUND: u8 = 2;
const TAG_LOSS: u8 = 3;
const TAG_LEDGER: u8 = 4;
const TAG_EXPORT: u8 = 5;
const TAG_RESEED: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_SETUP_OK: u8 = 16;
const TAG_ROUND_REPLY: u8 = 17;
const TAG_LOSS_REPLY: u8 = 18;
const TAG_LEDGER_REPLY: u8 = 19;
const TAG_WARM_REPLY: u8 = 20;
const TAG_RESEED_OK: u8 = 21;
const TAG_ERROR: u8 = 22;
const TAG_SUBMIT: u8 = 32;
const TAG_STATUS: u8 = 33;
const TAG_PREDICT: u8 = 34;
const TAG_JOBS: u8 = 35;
const TAG_SUBMITTED: u8 = 48;
const TAG_STATUS_REPLY: u8 = 49;
const TAG_PREDICT_REPLY: u8 = 50;
const TAG_JOBS_REPLY: u8 = 51;
const TAG_REJECTED: u8 = 52;

/// FNV-1a 64-bit hash — the per-frame checksum (same constants as the
/// checkpoint format's integrity hash).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// A node's training shard in wire form; rebuilt into a [`Shard`] on the
/// worker with bit-identical `f32` contents.
#[derive(Debug, Clone, PartialEq)]
pub struct WireShard {
    /// Per-sample labels (length = logical rows × label width).
    pub labels: Vec<f32>,
    /// Design-matrix payload in the storage layout the coordinator's
    /// density policy selected.
    pub data: WireShardData,
}

/// Storage layout of a [`WireShard`]'s design matrix.
#[derive(Debug, Clone, PartialEq)]
pub enum WireShardData {
    /// Row-major dense values.
    Dense {
        /// Logical row count.
        rows: u32,
        /// Column (feature) count.
        cols: u32,
        /// `rows * cols` values, row-major.
        vals: Vec<f32>,
    },
    /// Compressed sparse rows as per-row `(column, value)` lists.
    Csr {
        /// Column (feature) count.
        cols: u32,
        /// One `(column, value)` list per row, columns ascending.
        rows: Vec<Vec<(u32, f32)>>,
    },
}

impl WireShard {
    /// Capture a shard for shipment (after the coordinator's storage
    /// policy has been applied, so worker and in-process backends see the
    /// same representation).
    pub fn from_shard(shard: &Shard) -> WireShard {
        let data = match &shard.data {
            ShardData::Dense(m) => WireShardData::Dense {
                rows: m.rows as u32,
                cols: m.cols as u32,
                vals: m.to_vec(),
            },
            ShardData::Csr(c) => {
                let mut rows = Vec::with_capacity(c.rows);
                for i in 0..c.rows {
                    let (idx, vals) = c.row(i);
                    rows.push(idx.iter().copied().zip(vals.iter().copied()).collect());
                }
                WireShardData::Csr {
                    cols: c.cols as u32,
                    rows,
                }
            }
            // a mapped shard ships in its on-disk layout's wire form, so
            // the worker rebuilds the same resident representation the
            // coordinator's backend computes on (bit-identical f32s)
            ShardData::Mapped(m) if m.is_csr() => {
                let mut rows = Vec::with_capacity(m.rows());
                for i in 0..m.rows() {
                    let (idx, vals) = m.csr_row(i);
                    rows.push(idx.iter().copied().zip(vals.iter().copied()).collect());
                }
                WireShardData::Csr {
                    cols: m.cols() as u32,
                    rows,
                }
            }
            ShardData::Mapped(m) => {
                let mat = m.to_matrix();
                WireShardData::Dense {
                    rows: mat.rows as u32,
                    cols: mat.cols as u32,
                    vals: mat.to_vec(),
                }
            }
        };
        WireShard {
            labels: shard.labels.clone(),
            data,
        }
    }

    /// Rebuild the shard on the worker side.  `width` is the label width
    /// shipped in the `Setup` envelope.
    pub fn to_shard(&self, width: usize) -> anyhow::Result<Shard> {
        match &self.data {
            WireShardData::Dense { rows, cols, vals } => {
                let (rows, cols) = (*rows as usize, *cols as usize);
                anyhow::ensure!(
                    rows.checked_mul(cols) == Some(vals.len()),
                    "dense shard shape {rows}x{cols} does not match {} value(s)",
                    vals.len()
                );
                Ok(Shard::dense(
                    Matrix::from_flat(rows, cols, vals),
                    self.labels.clone(),
                    width,
                ))
            }
            WireShardData::Csr { cols, rows } => {
                let cols = *cols as usize;
                for (i, row) in rows.iter().enumerate() {
                    for &(j, _) in row {
                        anyhow::ensure!(
                            (j as usize) < cols,
                            "csr shard row {i} references column {j} >= {cols}"
                        );
                    }
                }
                Ok(Shard {
                    data: ShardData::Csr(Arc::new(CsrMatrix::from_rows(cols, rows.clone()))),
                    labels: self.labels.clone(),
                    width,
                })
            }
        }
    }
}

/// The `Setup` envelope: everything a standalone worker process needs to
/// reconstruct one node's `NodeWorker` exactly as `driver::build_workers`
/// would in process.
#[derive(Debug, Clone, PartialEq)]
pub struct Setup {
    /// This node's index in the cluster roster.
    pub node: u32,
    /// Cluster size (enters the block regularizer `1/(N*gamma) + rho_c`).
    pub nodes: u32,
    /// Global feature count.
    pub n_features: u32,
    /// Label width (1 for scalar losses, `k` for softmax).
    pub width: u32,
    /// `true` selects `SolveMode::Direct`; `false` selects CG with the
    /// config's `cg_iters`.
    pub direct_mode: bool,
    /// Full solver/platform config as canonical JSON (`Config::to_json`).
    pub config: String,
    /// This node's training shard.
    pub shard: WireShard,
}

/// A fit job description for `psfit serve`: a synthetic-problem shape
/// plus the solver config to run it with.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Feature count.
    pub n: u32,
    /// Total sample count (split across nodes).
    pub m: u32,
    /// Requested node count (clamped to the daemon's worker fleet).
    pub nodes: u32,
    /// Fraction of zero entries in the ground-truth weights.
    pub sparsity: f64,
    /// Design-matrix density in `(0, 1]`.
    pub density: f64,
    /// Label noise standard deviation.
    pub noise_std: f64,
    /// Data-generation seed.
    pub seed: u64,
    /// ℓ0 budget; `0` means "derive from the sparsity level".
    pub kappa: u32,
    /// Solver config as canonical JSON; empty selects the defaults.
    pub config: String,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            n: 200,
            m: 1600,
            nodes: 2,
            sparsity: 0.8,
            density: 1.0,
            noise_std: 0.1,
            seed: 42,
            kappa: 0,
            config: String::new(),
        }
    }
}

/// A job's status snapshot as reported by the daemon.
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatus {
    /// Job id.
    pub job: u64,
    /// Lifecycle phase code (see `serve::JobPhase`).
    pub phase: u8,
    /// Whether the solver hit its tolerances.
    pub converged: bool,
    /// Outer iterations run.
    pub iters: u64,
    /// Support size of the fitted model.
    pub support_len: u64,
    /// Regularized objective at the fitted point.
    pub objective: f64,
    /// Solve wall time in seconds.
    pub wall_seconds: f64,
    /// Failure message when the phase is `Failed`, else empty.
    pub message: String,
}

/// One row of the daemon's job listing.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSummary {
    /// Job id.
    pub job: u64,
    /// Lifecycle phase code (see `serve::JobPhase`).
    pub phase: u8,
    /// Client-supplied job name.
    pub name: String,
    /// Failure detail when the phase is `Failed`, else empty — carried in
    /// the listing so `psfit jobs` can say *why* a job failed even after
    /// the daemon restarted and replayed the entry from its journal.
    pub message: String,
}

/// Every message that crosses a psfit socket, as one codec.
///
/// Tags 1–7 flow coordinator→worker, 16–22 worker→coordinator, 32–35
/// serve-client→daemon, and 48–52 daemon→client.  `Error` is valid in any
/// reply position.
#[derive(Debug, Clone, PartialEq)]
pub enum WireCommand {
    /// Ship a node its shard + config; must precede any `Round`.
    Setup(Box<Setup>),
    /// One consensus round: broadcast `z`, expect a `RoundReply`.
    Round {
        /// Coordinator round counter, echoed back in the reply.
        round: u64,
        /// The consensus iterate.
        z: Vec<f64>,
    },
    /// Request the node's current loss value.
    Loss,
    /// Request the node's transfer ledger.
    Ledger,
    /// Request the node's warm state (sparsity-path checkpointing).
    Export,
    /// Reinstall warm state under new block penalties.
    Reseed {
        /// Local penalty `rho_l`.
        rho_l: f64,
        /// Consensus penalty `rho_c`.
        rho_c: f64,
        /// Block regularizer.
        reg: f64,
        /// Warm states; the worker picks the entry matching its node id.
        states: Vec<WarmState>,
    },
    /// Close the session cleanly.
    Shutdown,
    /// Setup acknowledgement.
    SetupOk {
        /// The node that finished construction.
        node: u32,
    },
    /// A node's round result.
    RoundReply {
        /// Replying node.
        node: u32,
        /// Echo of the request's round counter.
        round: u64,
        /// Local primal iterate.
        x: Vec<f64>,
        /// Scaled dual iterate.
        u: Vec<f64>,
    },
    /// Loss response.
    LossReply {
        /// The node's local objective contribution.
        value: f64,
    },
    /// Ledger response.
    LedgerReply(Box<TransferLedger>),
    /// Warm-state response.
    WarmReply(Box<WarmState>),
    /// Reseed acknowledgement.
    ReseedOk {
        /// The node that reinstalled its state.
        node: u32,
    },
    /// Failure report; valid in any reply position.
    Error {
        /// Human-readable cause.
        message: String,
    },
    /// Submit a fit job to the daemon.
    Submit {
        /// Client-chosen display name.
        name: String,
        /// Problem + config description.
        spec: JobSpec,
    },
    /// Poll one job's status.
    Status {
        /// Job id.
        job: u64,
    },
    /// Score a sparse feature vector against a fitted model.
    Predict {
        /// Job id of the fitted model.
        job: u64,
        /// `(feature index, value)` pairs, any order.
        features: Vec<(u32, f64)>,
    },
    /// List all jobs.
    Jobs,
    /// Submission acknowledgement.
    Submitted {
        /// Assigned job id.
        job: u64,
    },
    /// Status response.
    StatusReply(Box<JobStatus>),
    /// Prediction response: one score per class.
    PredictReply {
        /// `width` scores.
        values: Vec<f64>,
    },
    /// Job-listing response.
    JobsReply {
        /// One row per job, id ascending.
        jobs: Vec<JobSummary>,
    },
    /// Structured refusal of a request the daemon could have parsed but
    /// will not serve — a draining daemon answers `Submit` with this so
    /// clients can distinguish "shutting down, don't retry here" from a
    /// transport failure (which the client *does* retry through).
    Rejected {
        /// Machine-greppable cause, e.g. `draining: ...`.
        reason: String,
    },
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

fn w_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn w_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    w_u32(out, xs.len() as u32);
    for &x in xs {
        w_f64(out, x);
    }
}

fn w_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    w_u32(out, xs.len() as u32);
    for &x in xs {
        w_f32(out, x);
    }
}

fn w_warm(out: &mut Vec<u8>, s: &WarmState) {
    w_u32(out, s.node as u32);
    w_f64s(out, &s.x);
    w_f64s(out, &s.u);
    w_f32s(out, &s.omega);
    w_f32s(out, &s.nu);
    w_u32(out, s.preds.len() as u32);
    for p in &s.preds {
        w_f32s(out, p);
    }
}

fn w_ledger(out: &mut Vec<u8>, l: &TransferLedger) {
    w_u64(out, l.h2d_bytes);
    w_u64(out, l.d2h_bytes);
    w_f64(out, l.copy_seconds);
    w_u64(out, l.net_up_bytes);
    w_u64(out, l.net_down_bytes);
    w_u64(out, l.net_resync_bytes);
    w_u64(out, l.host_copy_saved_bytes);
    w_u64(out, l.net_alloc_saved_bytes);
    w_u64(out, l.gram_builds);
    w_u64(out, l.chol_factorizations);
    w_u64(out, l.chol_reuses);
    w_u64(out, l.wire_frames);
}

/// Encode a `Round` payload straight from a borrowed iterate — the
/// per-round hot path; the coordinator encodes once and writes the same
/// bytes to every live peer.
pub fn encode_round_payload(round: u64, z: &[f64], out: &mut Vec<u8>) {
    out.clear();
    w_u8(out, TAG_ROUND);
    w_u64(out, round);
    w_f64s(out, z);
}

impl WireCommand {
    /// Short tag name for diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            WireCommand::Setup(_) => "Setup",
            WireCommand::Round { .. } => "Round",
            WireCommand::Loss => "Loss",
            WireCommand::Ledger => "Ledger",
            WireCommand::Export => "Export",
            WireCommand::Reseed { .. } => "Reseed",
            WireCommand::Shutdown => "Shutdown",
            WireCommand::SetupOk { .. } => "SetupOk",
            WireCommand::RoundReply { .. } => "RoundReply",
            WireCommand::LossReply { .. } => "LossReply",
            WireCommand::LedgerReply(_) => "LedgerReply",
            WireCommand::WarmReply(_) => "WarmReply",
            WireCommand::ReseedOk { .. } => "ReseedOk",
            WireCommand::Error { .. } => "Error",
            WireCommand::Submit { .. } => "Submit",
            WireCommand::Status { .. } => "Status",
            WireCommand::Predict { .. } => "Predict",
            WireCommand::Jobs => "Jobs",
            WireCommand::Submitted { .. } => "Submitted",
            WireCommand::StatusReply(_) => "StatusReply",
            WireCommand::PredictReply { .. } => "PredictReply",
            WireCommand::JobsReply { .. } => "JobsReply",
            WireCommand::Rejected { .. } => "Rejected",
        }
    }

    /// Serialize the payload (tag byte + fields) into `out` (cleared
    /// first).
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.clear();
        match self {
            WireCommand::Setup(s) => {
                w_u8(out, TAG_SETUP);
                w_u32(out, s.node);
                w_u32(out, s.nodes);
                w_u32(out, s.n_features);
                w_u32(out, s.width);
                w_u8(out, s.direct_mode as u8);
                w_str(out, &s.config);
                w_f32s(out, &s.shard.labels);
                match &s.shard.data {
                    WireShardData::Dense { rows, cols, vals } => {
                        w_u8(out, 0);
                        w_u32(out, *rows);
                        w_u32(out, *cols);
                        w_f32s(out, vals);
                    }
                    WireShardData::Csr { cols, rows } => {
                        w_u8(out, 1);
                        w_u32(out, *cols);
                        w_u32(out, rows.len() as u32);
                        for row in rows {
                            w_u32(out, row.len() as u32);
                            for &(j, v) in row {
                                w_u32(out, j);
                                w_f32(out, v);
                            }
                        }
                    }
                }
            }
            WireCommand::Round { round, z } => encode_round_payload(*round, z, out),
            WireCommand::Loss => w_u8(out, TAG_LOSS),
            WireCommand::Ledger => w_u8(out, TAG_LEDGER),
            WireCommand::Export => w_u8(out, TAG_EXPORT),
            WireCommand::Reseed {
                rho_l,
                rho_c,
                reg,
                states,
            } => {
                w_u8(out, TAG_RESEED);
                w_f64(out, *rho_l);
                w_f64(out, *rho_c);
                w_f64(out, *reg);
                w_u32(out, states.len() as u32);
                for s in states {
                    w_warm(out, s);
                }
            }
            WireCommand::Shutdown => w_u8(out, TAG_SHUTDOWN),
            WireCommand::SetupOk { node } => {
                w_u8(out, TAG_SETUP_OK);
                w_u32(out, *node);
            }
            WireCommand::RoundReply { node, round, x, u } => {
                w_u8(out, TAG_ROUND_REPLY);
                w_u32(out, *node);
                w_u64(out, *round);
                w_f64s(out, x);
                w_f64s(out, u);
            }
            WireCommand::LossReply { value } => {
                w_u8(out, TAG_LOSS_REPLY);
                w_f64(out, *value);
            }
            WireCommand::LedgerReply(l) => {
                w_u8(out, TAG_LEDGER_REPLY);
                w_ledger(out, l);
            }
            WireCommand::WarmReply(s) => {
                w_u8(out, TAG_WARM_REPLY);
                w_warm(out, s);
            }
            WireCommand::ReseedOk { node } => {
                w_u8(out, TAG_RESEED_OK);
                w_u32(out, *node);
            }
            WireCommand::Error { message } => {
                w_u8(out, TAG_ERROR);
                w_str(out, message);
            }
            WireCommand::Submit { name, spec } => {
                w_u8(out, TAG_SUBMIT);
                w_str(out, name);
                w_u32(out, spec.n);
                w_u32(out, spec.m);
                w_u32(out, spec.nodes);
                w_f64(out, spec.sparsity);
                w_f64(out, spec.density);
                w_f64(out, spec.noise_std);
                w_u64(out, spec.seed);
                w_u32(out, spec.kappa);
                w_str(out, &spec.config);
            }
            WireCommand::Status { job } => {
                w_u8(out, TAG_STATUS);
                w_u64(out, *job);
            }
            WireCommand::Predict { job, features } => {
                w_u8(out, TAG_PREDICT);
                w_u64(out, *job);
                w_u32(out, features.len() as u32);
                for &(j, v) in features {
                    w_u32(out, j);
                    w_f64(out, v);
                }
            }
            WireCommand::Jobs => w_u8(out, TAG_JOBS),
            WireCommand::Submitted { job } => {
                w_u8(out, TAG_SUBMITTED);
                w_u64(out, *job);
            }
            WireCommand::StatusReply(s) => {
                w_u8(out, TAG_STATUS_REPLY);
                w_u64(out, s.job);
                w_u8(out, s.phase);
                w_u8(out, s.converged as u8);
                w_u64(out, s.iters);
                w_u64(out, s.support_len);
                w_f64(out, s.objective);
                w_f64(out, s.wall_seconds);
                w_str(out, &s.message);
            }
            WireCommand::PredictReply { values } => {
                w_u8(out, TAG_PREDICT_REPLY);
                w_f64s(out, values);
            }
            WireCommand::JobsReply { jobs } => {
                w_u8(out, TAG_JOBS_REPLY);
                w_u32(out, jobs.len() as u32);
                for j in jobs {
                    w_u64(out, j.job);
                    w_u8(out, j.phase);
                    w_str(out, &j.name);
                    w_str(out, &j.message);
                }
            }
            WireCommand::Rejected { reason } => {
                w_u8(out, TAG_REJECTED);
                w_str(out, reason);
            }
        }
    }

    /// Decode a frame payload.  Every read is bounds-checked; truncated
    /// input, unknown tags, and trailing garbage are errors.
    pub fn decode(payload: &[u8]) -> anyhow::Result<WireCommand> {
        let mut c = Cur::new(payload);
        let tag = c.u8()?;
        let cmd = match tag {
            TAG_SETUP => {
                let node = c.u32()?;
                let nodes = c.u32()?;
                let n_features = c.u32()?;
                let width = c.u32()?;
                let direct_mode = c.u8()? != 0;
                let config = c.str()?;
                let labels = c.f32s()?;
                let data = match c.u8()? {
                    0 => {
                        let rows = c.u32()?;
                        let cols = c.u32()?;
                        let vals = c.f32s()?;
                        WireShardData::Dense { rows, cols, vals }
                    }
                    1 => {
                        let cols = c.u32()?;
                        let n_rows = c.len()?;
                        let mut rows = Vec::with_capacity(n_rows);
                        for _ in 0..n_rows {
                            let nnz = c.bounded_len(8)?;
                            let mut row = Vec::with_capacity(nnz);
                            for _ in 0..nnz {
                                let j = c.u32()?;
                                let v = c.f32()?;
                                row.push((j, v));
                            }
                            rows.push(row);
                        }
                        WireShardData::Csr { cols, rows }
                    }
                    t => anyhow::bail!("unknown shard storage tag {t}"),
                };
                WireCommand::Setup(Box::new(Setup {
                    node,
                    nodes,
                    n_features,
                    width,
                    direct_mode,
                    config,
                    shard: WireShard { labels, data },
                }))
            }
            TAG_ROUND => {
                let round = c.u64()?;
                let z = c.f64s()?;
                WireCommand::Round { round, z }
            }
            TAG_LOSS => WireCommand::Loss,
            TAG_LEDGER => WireCommand::Ledger,
            TAG_EXPORT => WireCommand::Export,
            TAG_RESEED => {
                let rho_l = c.f64()?;
                let rho_c = c.f64()?;
                let reg = c.f64()?;
                let n = c.bounded_len(4)?;
                let mut states = Vec::with_capacity(n);
                for _ in 0..n {
                    states.push(c.warm()?);
                }
                WireCommand::Reseed {
                    rho_l,
                    rho_c,
                    reg,
                    states,
                }
            }
            TAG_SHUTDOWN => WireCommand::Shutdown,
            TAG_SETUP_OK => WireCommand::SetupOk { node: c.u32()? },
            TAG_ROUND_REPLY => {
                let node = c.u32()?;
                let round = c.u64()?;
                let x = c.f64s()?;
                let u = c.f64s()?;
                WireCommand::RoundReply { node, round, x, u }
            }
            TAG_LOSS_REPLY => WireCommand::LossReply { value: c.f64()? },
            TAG_LEDGER_REPLY => {
                let l = TransferLedger {
                    h2d_bytes: c.u64()?,
                    d2h_bytes: c.u64()?,
                    copy_seconds: c.f64()?,
                    net_up_bytes: c.u64()?,
                    net_down_bytes: c.u64()?,
                    net_resync_bytes: c.u64()?,
                    host_copy_saved_bytes: c.u64()?,
                    net_alloc_saved_bytes: c.u64()?,
                    gram_builds: c.u64()?,
                    chol_factorizations: c.u64()?,
                    chol_reuses: c.u64()?,
                    wire_frames: c.u64()?,
                };
                WireCommand::LedgerReply(Box::new(l))
            }
            TAG_WARM_REPLY => WireCommand::WarmReply(Box::new(c.warm()?)),
            TAG_RESEED_OK => WireCommand::ReseedOk { node: c.u32()? },
            TAG_ERROR => WireCommand::Error { message: c.str()? },
            TAG_SUBMIT => {
                let name = c.str()?;
                let spec = JobSpec {
                    n: c.u32()?,
                    m: c.u32()?,
                    nodes: c.u32()?,
                    sparsity: c.f64()?,
                    density: c.f64()?,
                    noise_std: c.f64()?,
                    seed: c.u64()?,
                    kappa: c.u32()?,
                    config: c.str()?,
                };
                WireCommand::Submit { name, spec }
            }
            TAG_STATUS => WireCommand::Status { job: c.u64()? },
            TAG_PREDICT => {
                let job = c.u64()?;
                let n = c.bounded_len(12)?;
                let mut features = Vec::with_capacity(n);
                for _ in 0..n {
                    let j = c.u32()?;
                    let v = c.f64()?;
                    features.push((j, v));
                }
                WireCommand::Predict { job, features }
            }
            TAG_JOBS => WireCommand::Jobs,
            TAG_SUBMITTED => WireCommand::Submitted { job: c.u64()? },
            TAG_STATUS_REPLY => {
                let s = JobStatus {
                    job: c.u64()?,
                    phase: c.u8()?,
                    converged: c.u8()? != 0,
                    iters: c.u64()?,
                    support_len: c.u64()?,
                    objective: c.f64()?,
                    wall_seconds: c.f64()?,
                    message: c.str()?,
                };
                WireCommand::StatusReply(Box::new(s))
            }
            TAG_PREDICT_REPLY => WireCommand::PredictReply { values: c.f64s()? },
            TAG_JOBS_REPLY => {
                let n = c.bounded_len(17)?;
                let mut jobs = Vec::with_capacity(n);
                for _ in 0..n {
                    let job = c.u64()?;
                    let phase = c.u8()?;
                    let name = c.str()?;
                    let message = c.str()?;
                    jobs.push(JobSummary {
                        job,
                        phase,
                        name,
                        message,
                    });
                }
                WireCommand::JobsReply { jobs }
            }
            TAG_REJECTED => WireCommand::Rejected { reason: c.str()? },
            t => anyhow::bail!("unknown wire command tag {t}"),
        };
        c.done()?;
        Ok(cmd)
    }
}

/// Bounds-checked little-endian reader over a frame payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("frame offset overflow"))?;
        anyhow::ensure!(
            end <= self.buf.len(),
            "truncated frame: wanted {n} byte(s) at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// A `u32` element count, bounded by the bytes actually remaining at
    /// `min_elem_bytes` per element — a corrupted count cannot trigger a
    /// huge allocation.
    fn bounded_len(&mut self, min_elem_bytes: usize) -> anyhow::Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        anyhow::ensure!(
            n.saturating_mul(min_elem_bytes) <= remaining,
            "truncated frame: {n} element(s) of >= {min_elem_bytes} byte(s) but only {remaining} remain"
        );
        Ok(n)
    }

    /// A `u32` element count for variable-size elements (each at least
    /// one length prefix).
    fn len(&mut self) -> anyhow::Result<usize> {
        self.bounded_len(4)
    }

    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.bounded_len(1)?;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("invalid utf-8 in wire string"))?
            .to_string())
    }

    fn f64s(&mut self) -> anyhow::Result<Vec<f64>> {
        let n = self.bounded_len(8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.bounded_len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    fn warm(&mut self) -> anyhow::Result<WarmState> {
        let node = self.u32()? as usize;
        let x = self.f64s()?;
        let u = self.f64s()?;
        let omega = self.f32s()?;
        let nu = self.f32s()?;
        let blocks = self.len()?;
        let mut preds = Vec::with_capacity(blocks);
        for _ in 0..blocks {
            preds.push(self.f32s()?);
        }
        Ok(WarmState {
            node,
            x,
            u,
            omega,
            nu,
            preds,
        })
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "trailing garbage: {} byte(s) after the decoded command",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------
// framing + handshake
// ---------------------------------------------------------------------

/// Write one frame from an already-encoded payload; returns the total
/// bytes put on the wire (payload + [`FRAME_OVERHEAD`]).
pub fn write_payload<W: Write>(w: &mut W, payload: &[u8]) -> anyhow::Result<usize> {
    anyhow::ensure!(
        !payload.is_empty(),
        "EmptyFrame: refusing to write a zero-length frame"
    );
    anyhow::ensure!(
        payload.len() <= MAX_FRAME,
        "FrameTooLarge: payload length {} exceeds the {MAX_FRAME}-byte frame limit",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&fnv1a(payload).to_le_bytes())?;
    w.flush()?;
    Ok(payload.len() + FRAME_OVERHEAD)
}

/// Encode and write one command; returns the bytes put on the wire.
pub fn write_frame<W: Write>(w: &mut W, cmd: &WireCommand) -> anyhow::Result<usize> {
    let mut payload = Vec::new();
    cmd.encode(&mut payload);
    write_payload(w, &payload)
}

/// Read one frame.  `Ok(None)` means the peer closed the connection
/// cleanly at a frame boundary; mid-frame EOF, a bad length, a checksum
/// mismatch, or an undecodable payload is an error.  Read timeouts
/// configured on the stream surface as errors here, so a silent peer
/// cannot hang the caller forever.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Option<(WireCommand, usize)>> {
    let mut head = [0u8; 4];
    if !read_full_or_eof(r, &mut head)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(head) as usize;
    // validate the header BEFORE any allocation: a corrupted or hostile
    // length prefix must surface as a named error, never as an attempted
    // multi-GB allocation or a zero-length decode
    anyhow::ensure!(
        len >= 1,
        "EmptyFrame: zero-length frame header (corrupted stream or protocol mismatch)"
    );
    anyhow::ensure!(
        len <= MAX_FRAME,
        "FrameTooLarge: frame length {len} exceeds the {MAX_FRAME}-byte limit \
         (corrupted stream or protocol mismatch)"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("connection closed mid-frame: {e}"))?;
    let mut sum = [0u8; 8];
    r.read_exact(&mut sum)
        .map_err(|e| anyhow::anyhow!("connection closed before frame checksum: {e}"))?;
    anyhow::ensure!(
        u64::from_le_bytes(sum) == fnv1a(&payload),
        "frame checksum mismatch (corrupted stream)"
    );
    let cmd = WireCommand::decode(&payload)?;
    Ok(Some((cmd, len + FRAME_OVERHEAD)))
}

/// Fill `buf` completely, or return `Ok(false)` when the stream is at EOF
/// *before the first byte* (a clean close).  EOF after a partial read is
/// an error.
fn read_full_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> anyhow::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(false),
            Ok(0) => anyhow::bail!("connection closed mid-frame header"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => anyhow::bail!("socket read failed: {e}"),
        }
    }
    Ok(true)
}

fn handshake_bytes() -> [u8; 8] {
    let mut b = [0u8; 8];
    b[..4].copy_from_slice(MAGIC);
    b[4..].copy_from_slice(&VERSION.to_le_bytes());
    b
}

fn check_handshake(got: &[u8; 8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        &got[..4] == MAGIC,
        "not a psfit wire endpoint (bad handshake magic)"
    );
    let peer = u32::from_le_bytes([got[4], got[5], got[6], got[7]]);
    anyhow::ensure!(
        peer == VERSION,
        "wire protocol version mismatch: peer speaks v{peer}, this build speaks v{VERSION}"
    );
    Ok(())
}

/// Client side of the connection handshake: send ours, then validate the
/// peer's.  Returns the total bytes exchanged ([`HANDSHAKE_BYTES`]).
pub fn client_handshake<S: Read + Write>(s: &mut S) -> anyhow::Result<usize> {
    s.write_all(&handshake_bytes())?;
    s.flush()?;
    let mut got = [0u8; 8];
    s.read_exact(&mut got)
        .map_err(|e| anyhow::anyhow!("peer closed during handshake: {e}"))?;
    check_handshake(&got)?;
    Ok(HANDSHAKE_BYTES)
}

/// Server side of the connection handshake: validate the peer's first,
/// then send ours.  Returns the total bytes exchanged.
pub fn server_handshake<S: Read + Write>(s: &mut S) -> anyhow::Result<usize> {
    let mut got = [0u8; 8];
    s.read_exact(&mut got)
        .map_err(|e| anyhow::anyhow!("peer closed during handshake: {e}"))?;
    check_handshake(&got)?;
    s.write_all(&handshake_bytes())?;
    s.flush()?;
    Ok(HANDSHAKE_BYTES)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(cmd: &WireCommand) {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, cmd).unwrap();
        assert_eq!(n, buf.len());
        let mut r = &buf[..];
        let (back, m) = read_frame(&mut r).unwrap().expect("frame present");
        assert_eq!(m, n);
        assert_eq!(&back, cmd);
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF after");
    }

    #[test]
    fn simple_commands_roundtrip() {
        roundtrip(&WireCommand::Loss);
        roundtrip(&WireCommand::Ledger);
        roundtrip(&WireCommand::Export);
        roundtrip(&WireCommand::Shutdown);
        roundtrip(&WireCommand::Jobs);
        roundtrip(&WireCommand::SetupOk { node: 3 });
        roundtrip(&WireCommand::ReseedOk { node: 1 });
        roundtrip(&WireCommand::Submitted { job: 9 });
        roundtrip(&WireCommand::Status { job: 2 });
        roundtrip(&WireCommand::LossReply { value: -0.25 });
        roundtrip(&WireCommand::Error {
            message: "node 2 é gone".into(),
        });
        roundtrip(&WireCommand::Rejected {
            reason: "draining: not accepting new jobs".into(),
        });
    }

    #[test]
    fn job_listing_carries_failure_detail() {
        roundtrip(&WireCommand::JobsReply { jobs: Vec::new() });
        roundtrip(&WireCommand::JobsReply {
            jobs: vec![
                JobSummary {
                    job: 1,
                    phase: 2,
                    name: "ok".into(),
                    message: String::new(),
                },
                JobSummary {
                    job: 2,
                    phase: 3,
                    name: "broken".into(),
                    message: "quorum lost: 2 worker death(s)".into(),
                },
            ],
        });
    }

    #[test]
    fn round_payload_helper_matches_enum_encoding() {
        let z = vec![1.5, -2.25, f64::MIN_POSITIVE];
        let mut a = Vec::new();
        encode_round_payload(7, &z, &mut a);
        let mut b = Vec::new();
        WireCommand::Round { round: 7, z }.encode(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn checksum_detects_corruption() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &WireCommand::LossReply { value: 1.0 }).unwrap();
        let mid = buf.len() / 2;
        buf[mid] ^= 0x40;
        let err = read_frame(&mut &buf[..]).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("length"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_hang() {
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &WireCommand::RoundReply {
                node: 0,
                round: 1,
                x: vec![1.0; 8],
                u: vec![2.0; 8],
            },
        )
        .unwrap();
        for cut in [1, 3, 5, buf.len() - 1] {
            assert!(
                read_frame(&mut &buf[..cut]).is_err(),
                "cut at {cut} must error"
            );
        }
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut &buf[..]).unwrap_err().to_string();
        assert!(err.contains("FrameTooLarge"), "{err}");
    }

    #[test]
    fn zero_length_header_is_a_named_error_not_a_panic() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 8]);
        let err = read_frame(&mut &buf[..]).unwrap_err().to_string();
        assert!(err.contains("EmptyFrame"), "{err}");
        // the writer refuses to produce such a frame in the first place
        let err = write_payload(&mut Vec::new(), &[]).unwrap_err().to_string();
        assert!(err.contains("EmptyFrame"), "{err}");
    }

    /// Property: mutating any byte(s) of a valid frame never panics the
    /// decoder — every outcome is `Ok` (mutation landed somewhere
    /// semantically inert) or a clean error.
    #[test]
    fn prop_mutated_frames_never_panic_the_decoder() {
        use crate::util::testkit::{run_prop, PropConfig};
        let templates: Vec<Vec<u8>> = {
            let cmds = vec![
                WireCommand::Loss,
                WireCommand::SetupOk { node: 3 },
                WireCommand::Round {
                    round: 9,
                    z: vec![1.0, -2.5, 3.25],
                },
                WireCommand::RoundReply {
                    node: 1,
                    round: 9,
                    x: vec![0.5; 6],
                    u: vec![-0.5; 6],
                },
                WireCommand::Reseed {
                    rho_l: 2.0,
                    rho_c: 1.0,
                    reg: 0.5,
                    states: vec![WarmState {
                        node: 0,
                        x: vec![1.0, 2.0],
                        u: vec![0.0, 0.1],
                        omega: vec![0.5; 4],
                        nu: vec![0.25; 4],
                        preds: vec![vec![1.0; 4], vec![2.0; 4]],
                    }],
                },
                WireCommand::Submit {
                    name: "job".into(),
                    spec: JobSpec::default(),
                },
                WireCommand::Error {
                    message: "boom".into(),
                },
            ];
            cmds.iter()
                .map(|c| {
                    let mut buf = Vec::new();
                    write_frame(&mut buf, c).unwrap();
                    buf
                })
                .collect()
        };
        run_prop("mutated_frames_never_panic", PropConfig::default(), |rng, _size| {
            let mut frame = templates[rng.below(templates.len())].clone();
            // 1..=4 arbitrary byte mutations anywhere in the frame,
            // including the length prefix and the checksum trailer
            let flips = 1 + rng.below(4);
            for _ in 0..flips {
                let at = rng.below(frame.len());
                frame[at] ^= (1 + rng.below(255)) as u8;
            }
            // decoding must complete without panicking; errors are fine
            let _ = read_frame(&mut &frame[..]);
            Ok(())
        });
    }

    #[test]
    fn unknown_tag_and_trailing_garbage_rejected() {
        assert!(WireCommand::decode(&[200]).is_err());
        let mut payload = Vec::new();
        WireCommand::Loss.encode(&mut payload);
        payload.push(0);
        let err = WireCommand::decode(&payload).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn corrupted_inner_count_cannot_alloc_huge() {
        // a Reseed frame whose state count claims 2^32-1 entries must be
        // rejected by the bounded-count check, not attempted
        let mut payload = Vec::new();
        w_u8(&mut payload, TAG_RESEED);
        w_f64(&mut payload, 1.0);
        w_f64(&mut payload, 1.0);
        w_f64(&mut payload, 1.0);
        w_u32(&mut payload, u32::MAX);
        let err = WireCommand::decode(&payload).unwrap_err().to_string();
        assert!(err.contains("truncated frame"), "{err}");
    }

    #[test]
    fn handshake_roundtrip_and_mismatch() {
        let b = handshake_bytes();
        check_handshake(&b).unwrap();
        let mut wrong_magic = b;
        wrong_magic[0] = b'X';
        assert!(check_handshake(&wrong_magic)
            .unwrap_err()
            .to_string()
            .contains("magic"));
        let mut wrong_version = b;
        wrong_version[4] = 0xFF;
        assert!(check_handshake(&wrong_version)
            .unwrap_err()
            .to_string()
            .contains("version mismatch"));
    }

    #[test]
    fn ledger_survives_the_wire() {
        let mut l = TransferLedger::default();
        l.h2d_bytes = 1;
        l.d2h_bytes = 2;
        l.copy_seconds = 0.125;
        l.net_up_bytes = 3;
        l.net_down_bytes = 4;
        l.net_resync_bytes = 5;
        l.host_copy_saved_bytes = 6;
        l.net_alloc_saved_bytes = 7;
        l.gram_builds = 8;
        l.chol_factorizations = 9;
        l.chol_reuses = 10;
        l.wire_frames = 11;
        roundtrip(&WireCommand::LedgerReply(Box::new(l)));
    }
}

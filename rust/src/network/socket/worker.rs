//! The `psfit worker` process: hosts node-level solver state behind a
//! socket.
//!
//! A worker binds one listener and serves **one node session per
//! connection**: the coordinator's `Setup` frame carries the shard, the
//! config, and the node id, and every later frame on that connection
//! drives that node.  Sessions run on their own threads, so a single
//! worker process serves many concurrent jobs — the multiplexing
//! `psfit serve` relies on to share a fleet between tenants.
//!
//! The node recipe here mirrors `driver::build_workers` exactly (same
//! plan, penalties, loss, and solve mode, from the same config), which is
//! what makes a localhost socket cluster bit-identical to the in-process
//! transports.

use std::io::Write as _;

use crate::admm::LocalProx;
use crate::backend::native::{NativeBackend, SolveMode};
use crate::backend::BlockParams;
use crate::config::Config;
use crate::data::FeaturePlan;
use crate::losses::make_loss;
use crate::network::socket::wire::{self, Setup, WireCommand};
use crate::network::socket::{Endpoint, SocketListener, SocketStream};
use crate::network::NodeWorker;
use crate::util::json::Json;

/// Settings for a standalone worker process.
#[derive(Debug, Clone)]
pub struct WorkerOpts {
    /// Address to listen on (`host:port`, port `0` for ephemeral, or
    /// `unix:/path`).
    pub listen: String,
    /// Survive listener-level failures: instead of exiting when the
    /// listener dies (address yanked, fd exhaustion, transient OS error),
    /// re-bind the same address under a capped backoff and keep serving.
    /// Session-level drops — a coordinator crashing mid-fit — are always
    /// survived regardless of this flag, because each connection is its
    /// own session and the accept loop never stops.
    pub reconnect: bool,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            listen: "127.0.0.1:0".to_string(),
            reconnect: false,
        }
    }
}

/// Run a worker until the process is killed: bind, announce the bound
/// address on stdout (`psfit worker listening on <addr>` — scripts and the
/// CI smoke job parse this line), and serve sessions forever.  With
/// `opts.reconnect`, a dead listener is re-bound (capped backoff, seeded
/// jitter) instead of taking the process down — pair it with a fixed
/// port, since an ephemeral re-bind would land elsewhere.
pub fn run_worker(opts: &WorkerOpts) -> anyhow::Result<()> {
    let ep = Endpoint::parse(&opts.listen);
    let listener = SocketListener::bind(&ep)?;
    println!("psfit worker listening on {}", listener.local_endpoint());
    let _ = std::io::stdout().flush();
    if !opts.reconnect {
        return serve_connections(listener, None);
    }
    let mut listener = Some(listener);
    let mut backoff = crate::util::backoff::Backoff::new(
        std::time::Duration::from_millis(50),
        std::time::Duration::from_secs(2),
        crate::network::socket::connect_backoff_seed(&ep),
    );
    loop {
        match listener.take() {
            Some(l) => {
                backoff.reset();
                if let Err(err) = serve_connections(l, None) {
                    eprintln!("[worker] listener died ({err}); re-binding {}", opts.listen);
                }
            }
            None => match SocketListener::bind(&Endpoint::parse(&opts.listen)) {
                Ok(l) => {
                    println!("psfit worker listening on {}", l.local_endpoint());
                    let _ = std::io::stdout().flush();
                    listener = Some(l);
                }
                Err(e) => {
                    eprintln!("[worker] re-bind failed ({e}); retrying");
                    backoff.sleep_next();
                }
            },
        }
    }
}

/// Spawn an in-process worker on an ephemeral localhost port and return
/// its address.  The thread is detached and lives for the rest of the
/// process — tests and `psfit serve --local-fleet` use this to stand up a
/// fleet without child processes.
pub fn spawn_local_worker() -> anyhow::Result<String> {
    spawn_worker_thread(None)
}

/// [`spawn_local_worker`], except every session drops its connection
/// without replying after serving `die_after_rounds` rounds — a simulated
/// worker crash for the degradation tests.
pub fn spawn_flaky_worker(die_after_rounds: usize) -> anyhow::Result<String> {
    spawn_worker_thread(Some(die_after_rounds))
}

fn spawn_worker_thread(fault: Option<usize>) -> anyhow::Result<String> {
    let listener = SocketListener::bind(&Endpoint::parse("127.0.0.1:0"))?;
    let addr = listener.local_endpoint();
    std::thread::Builder::new()
        .name("psfit-worker".into())
        .spawn(move || {
            if let Err(e) = serve_connections(listener, fault) {
                eprintln!("[worker] listener exited: {e}");
            }
        })
        .map_err(|e| anyhow::anyhow!("cannot spawn worker thread: {e}"))?;
    Ok(addr)
}

fn serve_connections(listener: SocketListener, fault: Option<usize>) -> anyhow::Result<()> {
    loop {
        let stream = listener
            .accept()
            .map_err(|e| anyhow::anyhow!("accept failed: {e}"))?;
        std::thread::spawn(move || {
            // a session error is that session's problem, not the worker's:
            // log it and keep accepting
            if let Err(e) = session(stream, fault) {
                eprintln!("[worker] session ended: {e}");
            }
        });
    }
}

/// One connection = one node session.  Returns `Ok` on a clean close or
/// `Shutdown`; protocol violations reply with an `Error` frame (when the
/// socket still works) and end the session.
fn session(mut stream: SocketStream, fault: Option<usize>) -> anyhow::Result<()> {
    wire::server_handshake(&mut stream)?;
    let mut node: Option<NodeWorker> = None;
    let mut rounds_served = 0usize;
    loop {
        let Some((cmd, _)) = wire::read_frame(&mut stream)? else {
            return Ok(());
        };
        match cmd {
            WireCommand::Setup(setup) => match build_node(&setup) {
                Ok(w) => {
                    let id = w.id as u32;
                    node = Some(w);
                    wire::write_frame(&mut stream, &WireCommand::SetupOk { node: id })?;
                }
                Err(e) => return refuse(&mut stream, format!("setup failed: {e}")),
            },
            WireCommand::Round { round, z } => {
                if fault.is_some_and(|limit| rounds_served >= limit) {
                    // simulated crash: vanish mid-round without replying
                    return Ok(());
                }
                let w = require(&mut node, &mut stream, "round")?;
                // the wire counter is 1-based (0 is "no round yet"); node
                // schedules index rounds from 0 like the in-process
                // transports, so mini-batch chunks line up across them
                let (x, u) = w.round_at(round.saturating_sub(1), &z);
                rounds_served += 1;
                let reply = WireCommand::RoundReply {
                    node: w.id as u32,
                    round,
                    x,
                    u,
                };
                wire::write_frame(&mut stream, &reply)?;
            }
            WireCommand::Loss => {
                let w = require(&mut node, &mut stream, "loss")?;
                let value = w.loss_value();
                wire::write_frame(&mut stream, &WireCommand::LossReply { value })?;
            }
            WireCommand::Ledger => {
                let w = require(&mut node, &mut stream, "ledger")?;
                let reply = WireCommand::LedgerReply(Box::new(w.ledger()));
                wire::write_frame(&mut stream, &reply)?;
            }
            WireCommand::Export => {
                let w = require(&mut node, &mut stream, "export")?;
                let reply = WireCommand::WarmReply(Box::new(w.export_warm()));
                wire::write_frame(&mut stream, &reply)?;
            }
            WireCommand::Reseed {
                rho_l,
                rho_c,
                reg,
                states,
            } => {
                let w = require(&mut node, &mut stream, "reseed")?;
                let params = BlockParams { rho_l, rho_c, reg };
                match states.iter().find(|s| s.node == w.id) {
                    Some(ws) => {
                        w.reseed(ws, params);
                        let reply = WireCommand::ReseedOk { node: w.id as u32 };
                        wire::write_frame(&mut stream, &reply)?;
                    }
                    None => {
                        let id = w.id;
                        return refuse(&mut stream, format!("reseed has no state for node {id}"));
                    }
                }
            }
            WireCommand::Shutdown => return Ok(()),
            other => {
                return refuse(
                    &mut stream,
                    format!("worker cannot handle `{}`", other.name()),
                )
            }
        }
    }
}

/// Reply with an `Error` frame (best-effort) and end the session with the
/// same message.
fn refuse(stream: &mut SocketStream, message: String) -> anyhow::Result<()> {
    let _ = wire::write_frame(
        stream,
        &WireCommand::Error {
            message: message.clone(),
        },
    );
    anyhow::bail!("{message}")
}

/// The session's node, or an `Error` reply + session end when `cmd`
/// arrived before `Setup`.
fn require<'a>(
    node: &'a mut Option<NodeWorker>,
    stream: &mut SocketStream,
    what: &str,
) -> anyhow::Result<&'a mut NodeWorker> {
    match node {
        Some(w) => Ok(w),
        None => {
            let message = format!("`{what}` before setup");
            let _ = wire::write_frame(stream, &WireCommand::Error { message: message.clone() });
            anyhow::bail!("{message}")
        }
    }
}

/// Reconstruct one node exactly as `driver::build_workers` would have:
/// same feature plan, block penalties, loss, solve mode, and thread
/// count, all derived from the shipped config.  The shard arrives already
/// storage-resolved (the coordinator applied the dense/CSR policy), so no
/// policy runs here.
fn build_node(setup: &Setup) -> anyhow::Result<NodeWorker> {
    let cfg = Config::from_json(&Json::parse(&setup.config)?)?;
    let width = setup.width as usize;
    let shard = setup.shard.to_shard(width)?;
    let plan = FeaturePlan::new(
        setup.n_features as usize,
        cfg.platform.devices_per_node,
        usize::MAX >> 1,
    );
    let params = BlockParams {
        rho_l: cfg.solver.rho_l,
        rho_c: cfg.solver.rho_c,
        reg: cfg.solver.block_reg(setup.nodes as usize),
    };
    let loss = make_loss(cfg.loss, width.max(cfg.classes));
    let mode = if setup.direct_mode {
        SolveMode::Direct
    } else {
        SolveMode::Cg {
            iters: cfg.solver.cg_iters,
        }
    };
    let backend: Box<dyn crate::backend::NodeBackend> = Box::new(
        NativeBackend::new(&shard, &plan, loss, mode).with_threads(cfg.platform.threads),
    );
    Ok(NodeWorker::new(
        setup.node as usize,
        LocalProx::new(backend, plan, width),
        params,
        cfg.solver.inner_iters,
    )
    .with_minibatch(cfg.solver.minibatch, cfg.solver.minibatch_seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::socket::connect;
    use std::io::Write;
    use std::time::Duration;

    fn dial(addr: &str) -> SocketStream {
        let s = connect(&Endpoint::parse(addr), Duration::from_secs(2), 3).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s
    }

    #[test]
    fn commands_before_setup_get_a_clean_error() {
        let addr = spawn_local_worker().unwrap();
        let mut s = dial(&addr);
        wire::client_handshake(&mut s).unwrap();
        wire::write_frame(&mut s, &WireCommand::Loss).unwrap();
        match wire::read_frame(&mut s).unwrap() {
            Some((WireCommand::Error { message }, _)) => {
                assert!(message.contains("before setup"), "{message}")
            }
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    #[test]
    fn daemon_commands_are_refused_by_workers() {
        let addr = spawn_local_worker().unwrap();
        let mut s = dial(&addr);
        wire::client_handshake(&mut s).unwrap();
        wire::write_frame(&mut s, &WireCommand::Jobs).unwrap();
        match wire::read_frame(&mut s).unwrap() {
            Some((WireCommand::Error { message }, _)) => {
                assert!(message.contains("cannot handle"), "{message}")
            }
            other => panic!("expected error frame, got {other:?}"),
        }
    }

    #[test]
    fn bad_handshake_is_rejected_without_hanging() {
        let addr = spawn_local_worker().unwrap();
        let mut s = dial(&addr);
        // wrong magic: the worker drops the session; our next read sees EOF
        s.write_all(b"NOPEnope").unwrap();
        s.flush().unwrap();
        let mut buf = [0u8; 8];
        let got = std::io::Read::read(&mut s, &mut buf).unwrap_or(0);
        assert_eq!(got, 0, "worker should close on a bad handshake");
    }
}

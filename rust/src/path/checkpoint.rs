//! PSC1 — the on-disk checkpoint format for sparsity-path sweeps.
//!
//! Written after every completed path point, so a killed sweep resumes at
//! the last completed point with a **bit-identical** remaining trajectory
//! (the resume sees exactly the [`SolverState`] an uninterrupted run
//! would hand to the next point).  Everything is little-endian and
//! round-trips floats through `to_le_bytes`, so the restore is bit-exact
//! by construction.
//!
//! Layout:
//!
//! ```text
//! magic "PSC1" | u32 version | u64 problem_hash
//! | u32 completed_points | per point:
//!     u32 kappa | f64 rho_c | f64 rho_b | u8 warm | u32 iters
//!     | u8 converged | f64 objective | f64 wall_seconds
//!     | u64 gram_builds | u64 chol_factorizations | u64 chol_reuses
//!     | u32 support_len | support_len x u32
//! | u8 has_state | (when 1) SolverState:
//!     global: u32 dim | dim x f64 z | f64 t | dim x f64 s | f64 v
//!             | dim x f64 z_prev
//!     nodes:  u32 count | per node:
//!         u32 node | u32 dim | dim x f64 x | dim x f64 u
//!         | u32 mw | mw x f32 omega | mw x f32 nu
//!         | u32 blocks | per block: u32 len | len x f32 pred
//! ```
//!
//! The `problem_hash` fingerprints the dataset shape, solver settings,
//! and the expanded point list; [`load`]ing a checkpoint whose hash does
//! not match the current run is rejected by `path::run_path` so a stale
//! file can never silently seed a different sweep.  Writes go through a
//! temp file + rename, so a kill mid-write leaves the previous checkpoint
//! intact.
//!
//! This module also defines **PSF1**, the sibling format for mid-fit
//! snapshots of a *single* solve (`psfit train --checkpoint`, serve
//! jobs).  It reuses the same `SolverState` block, preceded by the
//! completed iteration count and the convergence trace so far:
//!
//! ```text
//! magic "PSF1" | u32 version | u64 problem_hash | u64 iters_done
//! | u32 records | per record:
//!     u32 iter | f64 primal | f64 dual | f64 bilinear | f64 wall
//!     | u32 participants | u32 max_lag
//! | SolverState (same layout as PSC1's state block)
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::{PathPoint, PathPointRecord};
use crate::admm::{GlobalState, SolverState};
use crate::config::Config;
use crate::data::Dataset;
use crate::metrics::IterRecord;
use crate::network::WarmState;

const MAGIC: &[u8; 4] = b"PSC1";
const VERSION: u32 = 1;

const FIT_MAGIC: &[u8; 4] = b"PSF1";
const FIT_VERSION: u32 = 2;

/// Everything a resumed sweep needs: the records of completed points and
/// the warm state to seed the next one.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Fingerprint of the run this checkpoint belongs to.
    pub problem_hash: u64,
    /// Records of every completed path point, in solve order.
    pub completed: Vec<PathPointRecord>,
    /// Warm state after the last completed point.  `None` for cold-mode
    /// sweeps (which resume by position only) and for degraded async
    /// sweeps whose export did not cover the full roster (a resume then
    /// cold-starts its next point instead of failing on reseed).
    pub state: Option<SolverState>,
}

// ---------------------------------------------------------------- hashing

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.bytes(&v.to_le_bytes());
    }
}

/// FNV-1a fingerprint of the quantities that must match for a checkpoint
/// to be resumable: dataset shape *and contents* (strided value samples,
/// all labels, the planted truth — a different seed/density/file on the
/// same shape changes the hash with overwhelming probability), every
/// trajectory-shaping setting (loss, classes, solver tolerances and
/// iteration counts, backend, storage policy, feature-plan width,
/// coordination), the path mode, and the expanded point list.
pub fn problem_hash(ds: &Dataset, cfg: &Config, points: &[PathPoint]) -> u64 {
    let mut h = Fnv::new();
    // dataset shape
    h.u64(ds.n_features as u64);
    h.u64(ds.width as u64);
    h.u64(ds.nodes() as u64);
    h.u64(ds.total_samples() as u64);
    // dataset contents (cheap fingerprint)
    for &x in &ds.x_true {
        h.f64(x);
    }
    for &i in &ds.support_true {
        h.u64(i as u64);
    }
    for shard in &ds.shards {
        h.u64(shard.rows() as u64);
        h.u64(shard.data.nnz() as u64);
        for &l in &shard.labels {
            h.f32(l);
        }
        match &shard.data {
            crate::data::ShardData::Dense(a) => {
                // logical row-major elements (padding excluded), so the
                // hash matches the historical contiguous layout bit-exactly
                let step = ((a.rows * a.cols) / 1024).max(1);
                for &v in (0..a.rows).flat_map(|i| a.row(i)).step_by(step) {
                    h.f32(v);
                }
            }
            crate::data::ShardData::Csr(c) => {
                let step = (c.nnz() / 1024).max(1);
                for v in c.values().step_by(step) {
                    h.f32(v);
                }
            }
            // mapped shards sample exactly like their resident layout, so
            // a checkpoint taken over `data.psd1` resumes over the same
            // file — and over the equivalent resident shard — unchanged
            crate::data::ShardData::Mapped(m) if m.is_csr() => {
                let step = (m.nnz() / 1024).max(1);
                for v in m.csr_values().step_by(step) {
                    h.f32(v);
                }
            }
            crate::data::ShardData::Mapped(m) => {
                let step = ((m.rows() * m.cols()) / 1024).max(1);
                for &v in (0..m.rows()).flat_map(|i| m.dense_row(i)).step_by(step) {
                    h.f32(v);
                }
            }
        }
    }
    // trajectory-shaping solver / platform / coordination settings
    h.f64(cfg.solver.rho_l);
    h.f64(cfg.solver.gamma);
    h.u64(cfg.solver.max_iters as u64);
    h.u64(cfg.solver.inner_iters as u64);
    h.u64(cfg.solver.cg_iters as u64);
    h.u64(cfg.solver.zt_iters as u64);
    h.u64(cfg.solver.polish as u64);
    h.f64(cfg.solver.tol_primal);
    h.f64(cfg.solver.tol_dual);
    h.f64(cfg.solver.tol_bilinear);
    h.u64(cfg.solver.minibatch as u64);
    h.u64(cfg.solver.minibatch_seed);
    h.u64(cfg.loss as u64);
    h.u64(cfg.classes as u64);
    h.u64(cfg.platform.backend as u64);
    h.u64(cfg.platform.sparse as u64);
    h.f64(cfg.platform.sparse_threshold);
    h.u64(cfg.platform.devices_per_node as u64);
    h.u64(cfg.coordinator.coordination as u64);
    h.f64(cfg.coordinator.quorum);
    h.u64(cfg.coordinator.max_staleness as u64);
    // the path itself
    h.u64(cfg.path.warm_start as u64);
    h.u64(cfg.path.direct as u64);
    h.u64(points.len() as u64);
    for p in points {
        h.u64(p.kappa as u64);
        h.f64(p.rho_c);
        h.f64(p.rho_b);
    }
    h.0
}

// ------------------------------------------------------------ primitives

fn w_u8<W: Write>(w: &mut W, v: u8) -> std::io::Result<()> {
    w.write_all(&[v])
}

fn w_u32<W: Write>(w: &mut W, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_u64<W: Write>(w: &mut W, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_f64<W: Write>(w: &mut W, v: f64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_f64s<W: Write>(w: &mut W, xs: &[f64]) -> std::io::Result<()> {
    w_u32(w, xs.len() as u32)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn w_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    w_u32(w, xs.len() as u32)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn r_u8<R: Read>(r: &mut R) -> std::io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn r_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_f64<R: Read>(r: &mut R) -> std::io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

/// Bound an element count read from the file by what the file could
/// possibly hold (`elem` = minimum bytes per element), so a corrupt
/// count field yields a clean error instead of a huge allocation.
fn bounded(n: usize, elem: u64, file_len: u64, what: &str) -> anyhow::Result<usize> {
    anyhow::ensure!(
        (n as u64).saturating_mul(elem) <= file_len,
        "corrupt checkpoint: {what} count {n} exceeds the file size"
    );
    Ok(n)
}

fn r_f64s<R: Read>(r: &mut R, file_len: u64) -> anyhow::Result<Vec<f64>> {
    let n = bounded(r_u32(r)? as usize, 8, file_len, "f64 vector")?;
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

fn r_f32s<R: Read>(r: &mut R, file_len: u64) -> anyhow::Result<Vec<f32>> {
    let n = bounded(r_u32(r)? as usize, 4, file_len, "f32 vector")?;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// -------------------------------------------------- solver-state block

fn w_state<W: Write>(w: &mut W, st: &SolverState) -> std::io::Result<()> {
    w_f64s(w, &st.global.z)?;
    w_f64(w, st.global.t)?;
    w_f64s(w, &st.global.s)?;
    w_f64(w, st.global.v)?;
    w_f64s(w, &st.global.z_prev)?;
    w_u32(w, st.nodes.len() as u32)?;
    for ws in &st.nodes {
        w_u32(w, ws.node as u32)?;
        w_f64s(w, &ws.x)?;
        w_f64s(w, &ws.u)?;
        w_f32s(w, &ws.omega)?;
        w_f32s(w, &ws.nu)?;
        w_u32(w, ws.preds.len() as u32)?;
        for p in &ws.preds {
            w_f32s(w, p)?;
        }
    }
    Ok(())
}

fn r_state<R: Read>(r: &mut R, file_len: u64) -> anyhow::Result<SolverState> {
    let z = r_f64s(r, file_len)?;
    let t = r_f64(r)?;
    let s = r_f64s(r, file_len)?;
    let v = r_f64(r)?;
    let z_prev = r_f64s(r, file_len)?;
    anyhow::ensure!(
        z.len() == s.len() && z.len() == z_prev.len(),
        "corrupt checkpoint: global vector lengths disagree"
    );
    // a node snapshot is >= 24 bytes on disk; a block >= 4
    let n_nodes = bounded(r_u32(r)? as usize, 24, file_len, "node state")?;
    let mut nodes = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        let node = r_u32(r)? as usize;
        let x = r_f64s(r, file_len)?;
        let u = r_f64s(r, file_len)?;
        let omega = r_f32s(r, file_len)?;
        let nu = r_f32s(r, file_len)?;
        let n_blocks = bounded(r_u32(r)? as usize, 4, file_len, "block")?;
        let mut preds = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            preds.push(r_f32s(r, file_len)?);
        }
        nodes.push(WarmState {
            node,
            x,
            u,
            omega,
            nu,
            preds,
        });
    }
    Ok(SolverState {
        global: GlobalState {
            z,
            t,
            s,
            v,
            z_prev,
        },
        nodes,
    })
}

// ------------------------------------------------------------------ save

/// Atomically persist a checkpoint: written to `<path>.tmp`, then renamed
/// over `path`, so a kill mid-write leaves the previous file intact.
pub fn save(path: &Path, ck: &Checkpoint) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = path.with_extension("psc1.tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(MAGIC)?;
        w_u32(&mut w, VERSION)?;
        w_u64(&mut w, ck.problem_hash)?;
        w_u32(&mut w, ck.completed.len() as u32)?;
        for p in &ck.completed {
            w_u32(&mut w, p.kappa as u32)?;
            w_f64(&mut w, p.rho_c)?;
            w_f64(&mut w, p.rho_b)?;
            w_u8(&mut w, p.warm as u8)?;
            w_u32(&mut w, p.iters as u32)?;
            w_u8(&mut w, p.converged as u8)?;
            w_f64(&mut w, p.objective)?;
            w_f64(&mut w, p.wall_seconds)?;
            w_u64(&mut w, p.gram_builds)?;
            w_u64(&mut w, p.chol_factorizations)?;
            w_u64(&mut w, p.chol_reuses)?;
            w_u32(&mut w, p.support.len() as u32)?;
            for &i in &p.support {
                w_u32(&mut w, i as u32)?;
            }
        }
        match &ck.state {
            None => w_u8(&mut w, 0)?,
            Some(st) => {
                w_u8(&mut w, 1)?;
                w_state(&mut w, st)?;
            }
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("committing checkpoint {}: {e}", path.display()))?;
    Ok(())
}

// ------------------------------------------------------------------ load

/// Read a checkpoint back, bit-exactly.  Fails cleanly on a bad
/// magic/version, a truncated file, or count fields exceeding what the
/// file could hold; hash compatibility is the *caller's* check (the
/// loader cannot know which run the bytes were meant for).
pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
    let file = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening checkpoint {}: {e}", path.display()))?;
    let file_len = file.metadata().map(|m| m.len()).unwrap_or(u64::MAX);
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a PSC1 checkpoint file");
    let version = r_u32(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let problem_hash = r_u64(&mut r)?;
    // a point record is >= 70 bytes on disk
    let n_points = bounded(r_u32(&mut r)? as usize, 70, file_len, "path point")?;
    let mut completed = Vec::with_capacity(n_points);
    for _ in 0..n_points {
        let kappa = r_u32(&mut r)? as usize;
        let rho_c = r_f64(&mut r)?;
        let rho_b = r_f64(&mut r)?;
        let warm = r_u8(&mut r)? != 0;
        let iters = r_u32(&mut r)? as usize;
        let converged = r_u8(&mut r)? != 0;
        let objective = r_f64(&mut r)?;
        let wall_seconds = r_f64(&mut r)?;
        let gram_builds = r_u64(&mut r)?;
        let chol_factorizations = r_u64(&mut r)?;
        let chol_reuses = r_u64(&mut r)?;
        let s_len = bounded(r_u32(&mut r)? as usize, 4, file_len, "support entry")?;
        let mut support = Vec::with_capacity(s_len);
        for _ in 0..s_len {
            support.push(r_u32(&mut r)? as usize);
        }
        completed.push(PathPointRecord {
            kappa,
            rho_c,
            rho_b,
            warm,
            iters,
            converged,
            objective,
            support,
            wall_seconds,
            gram_builds,
            chol_factorizations,
            chol_reuses,
        });
    }
    let state = match r_u8(&mut r)? {
        0 => None,
        _ => Some(r_state(&mut r, file_len)?),
    };
    Ok(Checkpoint {
        problem_hash,
        completed,
        state,
    })
}

// ------------------------------------------- fit checkpoints (PSF1)

/// Mid-fit snapshot of a single solve, written every
/// `solver.checkpoint_every` outer iterations by
/// `admm::solve_checkpointed`.  Resuming replays nothing: the loop
/// restarts at `iters_done` from the captured [`SolverState`], so the
/// remaining trace is bit-identical to an uninterrupted run.
#[derive(Debug, Clone, PartialEq)]
pub struct FitCheckpoint {
    /// Fingerprint of the fit this snapshot belongs to — the same
    /// [`problem_hash`] used by sweeps, taken with an empty point list.
    pub problem_hash: u64,
    /// Outer iterations completed when the snapshot was taken.
    pub iters_done: u64,
    /// Convergence records of the completed iterations, in order.
    pub trace: Vec<IterRecord>,
    /// Full solver state at the iteration boundary.
    pub state: SolverState,
}

/// Atomically persist a mid-fit snapshot: written to `<path>.psf1.tmp`,
/// then renamed over `path`, so a kill mid-write leaves the previous
/// snapshot intact.
pub fn save_fit(path: &Path, ck: &FitCheckpoint) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = path.with_extension("psf1.tmp");
    {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(FIT_MAGIC)?;
        w_u32(&mut w, FIT_VERSION)?;
        w_u64(&mut w, ck.problem_hash)?;
        w_u64(&mut w, ck.iters_done)?;
        w_u32(&mut w, ck.trace.len() as u32)?;
        for r in &ck.trace {
            w_u32(&mut w, r.iter as u32)?;
            w_f64(&mut w, r.primal)?;
            w_f64(&mut w, r.dual)?;
            w_f64(&mut w, r.bilinear)?;
            w_f64(&mut w, r.wall)?;
            w_u32(&mut w, r.participants as u32)?;
            w_u32(&mut w, r.max_lag as u32)?;
            w_u32(&mut w, r.restarts as u32)?;
        }
        w_state(&mut w, &ck.state)?;
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("committing fit checkpoint {}: {e}", path.display()))?;
    Ok(())
}

/// Read a mid-fit snapshot back, bit-exactly.  Same failure contract as
/// [`load`]: clean errors on a bad magic/version, truncation, or corrupt
/// count fields; hash compatibility is the caller's check.
pub fn load_fit(path: &Path) -> anyhow::Result<FitCheckpoint> {
    let file = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening fit checkpoint {}: {e}", path.display()))?;
    let file_len = file.metadata().map(|m| m.len()).unwrap_or(u64::MAX);
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == FIT_MAGIC, "not a PSF1 fit-checkpoint file");
    let version = r_u32(&mut r)?;
    anyhow::ensure!(
        version == FIT_VERSION,
        "unsupported fit-checkpoint version {version}"
    );
    let problem_hash = r_u64(&mut r)?;
    let iters_done = r_u64(&mut r)?;
    // an iteration record is 48 bytes on disk
    let n_recs = bounded(r_u32(&mut r)? as usize, 48, file_len, "iteration record")?;
    let mut trace = Vec::with_capacity(n_recs);
    for _ in 0..n_recs {
        trace.push(IterRecord {
            iter: r_u32(&mut r)? as usize,
            primal: r_f64(&mut r)?,
            dual: r_f64(&mut r)?,
            bilinear: r_f64(&mut r)?,
            wall: r_f64(&mut r)?,
            participants: r_u32(&mut r)? as usize,
            max_lag: r_u32(&mut r)? as usize,
            restarts: r_u32(&mut r)? as usize,
        });
    }
    let state = r_state(&mut r, file_len)?;
    Ok(FitCheckpoint {
        problem_hash,
        iters_done,
        trace,
        state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_checkpoint() -> Checkpoint {
        Checkpoint {
            problem_hash: 0xDEAD_BEEF_CAFE_F00D,
            completed: vec![PathPointRecord {
                kappa: 8,
                rho_c: 1.5,
                rho_b: 0.75,
                warm: true,
                iters: 42,
                converged: true,
                objective: -3.25e-2,
                support: vec![1, 4, 9],
                wall_seconds: 0.125,
                gram_builds: 4,
                chol_factorizations: 2,
                chol_reuses: 1,
            }],
            state: Some(SolverState {
                global: GlobalState {
                    z: vec![0.1, -0.2, 3.0e-17],
                    t: 2.5,
                    s: vec![1.0, 0.0, -1.0],
                    v: -0.625,
                    z_prev: vec![0.0, 0.25, f64::MIN_POSITIVE],
                },
                nodes: vec![WarmState {
                    node: 1,
                    x: vec![0.5, 0.25, -0.125],
                    u: vec![-1.0, 2.0, 0.0],
                    omega: vec![0.5f32, -0.25],
                    nu: vec![1.5f32, 0.0],
                    preds: vec![vec![0.125f32], vec![-2.5f32]],
                }],
            }),
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let ck = sample_checkpoint();
        let path = std::env::temp_dir().join("psfit_ck_roundtrip.psc");
        save(&path, &ck).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn roundtrip_without_state() {
        let mut ck = sample_checkpoint();
        ck.state = None;
        let path = std::env::temp_dir().join("psfit_ck_nostate.psc");
        save(&path, &ck).unwrap();
        assert_eq!(load(&path).unwrap(), ck);
    }

    #[test]
    fn rejects_garbage_and_wrong_version() {
        let path = std::env::temp_dir().join("psfit_ck_garbage.psc");
        std::fs::write(&path, b"nope").unwrap();
        assert!(load(&path).is_err());
        let mut bytes = b"PSC1".to_vec();
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_oversized_count_fields_cleanly() {
        // a corrupt count must be a clean error, not a huge allocation
        let path = std::env::temp_dir().join("psfit_ck_huge.psc");
        let mut bytes = b"PSC1".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version
        bytes.extend_from_slice(&0u64.to_le_bytes()); // hash
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd point count
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("exceeds the file size"), "{err}");
    }

    #[test]
    fn fit_roundtrip_is_bit_exact() {
        let ck = FitCheckpoint {
            problem_hash: 0x1234_5678_9ABC_DEF0,
            iters_done: 17,
            trace: vec![
                IterRecord {
                    iter: 0,
                    primal: 1.5,
                    dual: -2.5e-3,
                    bilinear: 3.0e-17,
                    wall: 0.25,
                    participants: 4,
                    max_lag: 0,
                    restarts: 0,
                },
                IterRecord {
                    iter: 16,
                    primal: f64::MIN_POSITIVE,
                    dual: 0.0,
                    bilinear: -0.0,
                    wall: 1.125,
                    participants: 3,
                    max_lag: 2,
                    restarts: 1,
                },
            ],
            state: sample_checkpoint().state.unwrap(),
        };
        let path = std::env::temp_dir().join("psfit_fit_roundtrip.psf");
        save_fit(&path, &ck).unwrap();
        let back = load_fit(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(
            back.trace[1].primal.to_bits(),
            ck.trace[1].primal.to_bits(),
            "float payloads survive bit-exactly"
        );
    }

    #[test]
    fn fit_loader_rejects_garbage_and_foreign_formats() {
        let path = std::env::temp_dir().join("psfit_fit_garbage.psf");
        std::fs::write(&path, b"nope").unwrap();
        assert!(load_fit(&path).is_err());
        // a PSC1 sweep checkpoint is not a PSF1 fit checkpoint
        save(&path, &sample_checkpoint()).unwrap();
        let err = load_fit(&path).unwrap_err().to_string();
        assert!(err.contains("PSF1"), "{err}");
        // corrupt record counts fail cleanly, without a huge allocation
        let mut bytes = b"PSF1".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_fit(&path).unwrap_err().to_string();
        assert!(err.contains("exceeds the file size"), "{err}");
    }

    #[test]
    fn hash_is_sensitive_to_points_and_shape() {
        let ds = crate::data::SyntheticSpec::regression(10, 30, 2).generate();
        let mut cfg = Config::default();
        cfg.path.budgets = vec![4, 2];
        let pts = cfg.path.points(&cfg.solver);
        let h0 = problem_hash(&ds, &cfg, &pts);
        assert_eq!(h0, problem_hash(&ds, &cfg, &pts), "hash is deterministic");

        let mut cfg2 = cfg.clone();
        cfg2.path.budgets = vec![4, 3];
        let pts2 = cfg2.path.points(&cfg2.solver);
        assert_ne!(h0, problem_hash(&ds, &cfg2, &pts2), "budgets change the hash");

        let ds2 = crate::data::SyntheticSpec::regression(11, 30, 2).generate();
        assert_ne!(h0, problem_hash(&ds2, &cfg, &pts), "shape changes the hash");

        // same shape, different contents (seed) — must still differ
        let mut spec3 = crate::data::SyntheticSpec::regression(10, 30, 2);
        spec3.seed = 7;
        let ds3 = spec3.generate();
        assert_ne!(h0, problem_hash(&ds3, &cfg, &pts), "contents change the hash");

        // trajectory-shaping settings — must differ
        let mut cfg4 = cfg.clone();
        cfg4.loss = crate::losses::LossKind::Logistic;
        assert_ne!(h0, problem_hash(&ds, &cfg4, &pts), "loss changes the hash");
        let mut cfg5 = cfg.clone();
        cfg5.solver.tol_primal = 1e-6;
        assert_ne!(h0, problem_hash(&ds, &cfg5, &pts), "tolerances change the hash");
    }
}

//! Warm-started sparsity-path subsystem: one incremental sweep instead of
//! N cold solves.
//!
//! The paper's experiments treat every (kappa, rho) cell of Table 1 /
//! Fig. 4 as an independent cold-started run, yet ADMM-family methods
//! amortize almost all of their cost across nearby problems via warm
//! starts (Deng et al., arXiv:1312.3040) and exact-sparse solvers branch
//! over budgets the same way (Anh-Nguyen & Uribe).  This module drives a
//! **descending** sequence of cardinality budgets kappa_1 > kappa_2 > ...
//! (optionally crossed with a rho ladder), warm-starting each solve from
//! the previous point's full [`SolverState`]:
//!
//!   * the coordinator's (z, t, s, v) continue their trajectory — a solve
//!     at kappa_{i+1} starts from the kappa_i optimum, which is already
//!     nearly feasible for the tighter budget;
//!   * every node's (x_i, u_i) and inner sharing-ADMM state carry over
//!     through [`crate::network::Cluster::reseed`];
//!   * the per-block Gram matrices are computed **once** for the whole
//!     sweep (they depend only on the data), and Cholesky factors are
//!     cached keyed by (block, penalties), so a rho-ladder revisit is a
//!     lookup instead of an O(w^3) refactorization — the reuse counters
//!     land in each [`PathPointRecord`].
//!
//! The handoff between points always goes through the serializable
//! [`SolverState`], which is exactly what [`checkpoint`] persists after
//! every completed point: a killed sweep resumes at the last completed
//! path point with a bit-identical remaining trajectory (pinned by
//! `tests/path.rs`).
//!
//! Entry points: `psfit path` (CLI), the JSON `"path"` config section,
//! and [`run_path`] for library users; `psfit pathbench` benchmarks warm
//! vs. cold across the density grid into `BENCH_path.json`.

pub mod checkpoint;

use crate::admm::{self, GlobalState, SolveOptions, SolveResult, SolverState};
use crate::backend::native::SolveMode;
use crate::backend::BlockParams;
use crate::config::{Config, SolverConfig};
use crate::data::Dataset;
use crate::driver;
use crate::losses::make_loss;
use crate::metrics::TransferLedger;
use crate::network::Cluster;
use crate::util::Stopwatch;

/// One (kappa, rho) node of a sparsity-path sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct PathPoint {
    /// Cardinality budget at this point.
    pub kappa: usize,
    /// Consensus penalty rho_c at this point.
    pub rho_c: f64,
    /// Bi-linear penalty rho_b at this point (the base config's
    /// rho_b/rho_c ratio is preserved along the ladder).
    pub rho_b: f64,
}

/// Configuration of the sparsity-path subsystem (JSON `"path"` section,
/// `psfit path` CLI flags).
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// Cardinality budgets, strictly descending (e.g. `[200, 100, 50]`).
    pub budgets: Vec<usize>,
    /// Optional rho_c ladder; each rung sweeps every budget.  Empty means
    /// a single rung at the base config's rho_c.
    pub rho_ladder: Vec<f64>,
    /// Warm-start each point from the previous one (the whole point of a
    /// path); `false` re-builds everything per point — the cold baseline
    /// `psfit pathbench` measures against.
    pub warm_start: bool,
    /// Checkpoint file: written after every completed point, resumed from
    /// automatically when it exists and matches the problem.
    pub checkpoint: Option<String>,
    /// Stop after this many completed points (test/benchmark hook that
    /// simulates a killed sweep; `None` runs the full path).
    pub limit: Option<usize>,
    /// Use the direct (cached-Cholesky) native solver so the keyed
    /// factorization cache pays off across rho revisits; `false` keeps
    /// the artifact-parallel CG mode.
    pub direct: bool,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            budgets: Vec::new(),
            rho_ladder: Vec::new(),
            warm_start: true,
            checkpoint: None,
            limit: None,
            direct: true,
        }
    }
}

impl PathConfig {
    /// Reject empty, non-descending, or degenerate sweeps.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.budgets.is_empty(),
            "path.budgets must list at least one cardinality budget"
        );
        for &k in &self.budgets {
            anyhow::ensure!(k >= 1, "path budgets must be >= 1");
        }
        for w in self.budgets.windows(2) {
            anyhow::ensure!(
                w[0] > w[1],
                "path.budgets must be strictly descending (got {} then {})",
                w[0],
                w[1]
            );
        }
        for &r in &self.rho_ladder {
            anyhow::ensure!(
                r.is_finite() && r > 0.0,
                "path.rho_ladder entries must be positive, got {r}"
            );
        }
        if let Some(l) = self.limit {
            anyhow::ensure!(l >= 1, "path.limit must be >= 1");
        }
        Ok(())
    }

    /// Expand the sweep: for every ladder rung (outer, in the given
    /// order) solve every budget (inner, descending).  The base config's
    /// rho_b/rho_c ratio (the paper's alpha rule) is preserved per rung.
    pub fn points(&self, base: &SolverConfig) -> Vec<PathPoint> {
        let ratio = base.rho_b / base.rho_c;
        let rungs: Vec<f64> = if self.rho_ladder.is_empty() {
            vec![base.rho_c]
        } else {
            self.rho_ladder.clone()
        };
        let mut out = Vec::with_capacity(rungs.len() * self.budgets.len());
        for &rho in &rungs {
            for &kappa in &self.budgets {
                out.push(PathPoint {
                    kappa,
                    rho_c: rho,
                    rho_b: rho * ratio,
                });
            }
        }
        out
    }
}

/// Everything one completed path point reports: the model-selection
/// quantities (support, objective) plus the reuse accounting that shows
/// what warm-starting saved.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PathPointRecord {
    /// Cardinality budget of this point.
    pub kappa: usize,
    /// Consensus penalty used at this point.
    pub rho_c: f64,
    /// Bi-linear penalty used at this point.
    pub rho_b: f64,
    /// Whether this point was warm-started from the previous one.
    pub warm: bool,
    /// Outer Bi-cADMM iterations the point needed.
    pub iters: usize,
    /// Whether the residual thresholds were met.
    pub converged: bool,
    /// Full regularized objective (Eq. 1) of the extracted solution.
    pub objective: f64,
    /// Recovered support (sorted flattened coefficient indices).
    pub support: Vec<usize>,
    /// Wall-clock seconds for this point (including any rebuild).
    pub wall_seconds: f64,
    /// Per-block Gram matrices computed for this point (0 on every
    /// warm point after the first — the sweep reuses them).
    pub gram_builds: u64,
    /// Cholesky factorizations computed at this point.
    pub chol_factorizations: u64,
    /// Cholesky factors served from the keyed cache at this point.
    pub chol_reuses: u64,
}

/// The full trace of a sparsity-path sweep, one record per completed
/// point in solve order.
///
/// ```
/// use psfit::path::{PathPointRecord, PathTrace};
/// let mut trace = PathTrace::default();
/// trace.points.push(PathPointRecord {
///     kappa: 8,
///     iters: 12,
///     support: vec![1, 5, 7],
///     ..Default::default()
/// });
/// let csv = trace.to_csv();
/// assert!(csv.starts_with("kappa,rho_c,rho_b,warm,iters"));
/// assert_eq!(csv.lines().count(), 2, "header + one point");
/// assert_eq!(trace.total_iters(), 12);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PathTrace {
    /// Completed points in solve order (ladder-major, budgets descending).
    pub points: Vec<PathPointRecord>,
}

impl PathTrace {
    /// Sum of outer iterations over all completed points — the quantity a
    /// warm sweep shrinks relative to a cold sequence.
    pub fn total_iters(&self) -> usize {
        self.points.iter().map(|p| p.iters).sum()
    }

    /// The last completed point, if any.
    pub fn last(&self) -> Option<&PathPointRecord> {
        self.points.last()
    }

    /// CSV rendering with header
    /// `kappa,rho_c,rho_b,warm,iters,converged,objective,support_size,wall_seconds,gram_builds,chol_factorizations,chol_reuses`.
    pub fn to_csv(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from(
            "kappa,rho_c,rho_b,warm,iters,converged,objective,support_size,\
             wall_seconds,gram_builds,chol_factorizations,chol_reuses\n",
        );
        for p in &self.points {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{:.6e},{},{:.6e},{},{},{}",
                p.kappa,
                p.rho_c,
                p.rho_b,
                p.warm,
                p.iters,
                p.converged,
                p.objective,
                p.support.len(),
                p.wall_seconds,
                p.gram_builds,
                p.chol_factorizations,
                p.chol_reuses
            );
        }
        out
    }
}

/// What [`run_path`] hands back.
pub struct PathOutcome {
    /// One record per completed point (checkpoint-restored points
    /// included, so the trace always covers the whole sweep so far).
    pub trace: PathTrace,
    /// The last point actually solved in this process (`None` when the
    /// checkpoint already covered every requested point).
    pub final_result: Option<SolveResult>,
    /// Points skipped because a matching checkpoint already covered them.
    pub resumed_points: usize,
}

/// Run the configured sparsity path over a dataset.
///
/// Builds the cluster once (warm mode) and hands the serializable
/// [`SolverState`] from each point to the next; with `cfg.path.checkpoint`
/// set, the state and trace are persisted after every completed point and
/// a matching checkpoint file is resumed from automatically.  `threaded`
/// selects the transport exactly like [`driver::fit_with_options`].
pub fn run_path(
    ds: &Dataset,
    cfg: &Config,
    opts: &SolveOptions,
    threaded: bool,
) -> anyhow::Result<PathOutcome> {
    let pcfg = &cfg.path;
    pcfg.validate()?;
    cfg.solver.validate()?;
    let points = pcfg.points(&cfg.solver);
    let dim = ds.n_features * ds.width;
    for p in &points {
        anyhow::ensure!(
            p.kappa <= dim,
            "path budget {} exceeds the coefficient dimension {dim}",
            p.kappa
        );
    }
    let hash = checkpoint::problem_hash(ds, cfg, &points);

    // ---- resume: a matching checkpoint skips its completed points ------
    let mut completed: Vec<PathPointRecord> = Vec::new();
    let mut state: Option<SolverState> = None;
    if let Some(ck_path) = &pcfg.checkpoint {
        let p = std::path::Path::new(ck_path);
        if p.exists() {
            let ck = checkpoint::load(p)?;
            anyhow::ensure!(
                ck.problem_hash == hash,
                "checkpoint {ck_path} was written for a different path run \
                 (dataset, budgets, ladder, or solver settings changed)"
            );
            completed = ck.completed;
            state = ck.state;
        }
    }
    let resumed_points = completed.len();

    let loss = make_loss(cfg.loss, ds.width.max(cfg.classes));
    let mode = if pcfg.direct {
        SolveMode::Direct
    } else {
        SolveMode::Cg {
            iters: cfg.solver.cg_iters,
        }
    };

    let mut cluster: Option<Box<dyn Cluster>> = None;
    let mut prev_ledger = TransferLedger::default();
    let mut final_result = None;
    // one allocation pool for the whole sweep: every point after the
    // first reuses the solver's consensus/polish/objective temporaries
    // (the avoided bytes ride each solve's net_alloc_saved_bytes)
    let mut scratch = admm::SolveScratch::default();
    let end = pcfg.limit.map(|l| l.min(points.len())).unwrap_or(points.len());

    for pt in points.iter().take(end).skip(resumed_points) {
        let watch = Stopwatch::start();
        let mut pc = cfg.clone();
        pc.solver.kappa = pt.kappa;
        pc.solver.rho_c = pt.rho_c;
        pc.solver.rho_b = pt.rho_b;
        let params = BlockParams {
            rho_l: pc.solver.rho_l,
            rho_c: pc.solver.rho_c,
            reg: pc.solver.block_reg(ds.nodes()),
        };

        // warm mode keeps one cluster for the whole sweep; cold mode
        // re-builds per point (Gram recompute and all), like a sequence
        // of independent `psfit train` runs
        if cluster.is_none() || !pcfg.warm_start {
            let workers = driver::build_workers_mode(ds, &pc, mode)?;
            cluster = Some(driver::build_cluster(workers, dim, &pc, threaded)?);
            prev_ledger = TransferLedger::default();
        }
        let cl = cluster.as_mut().unwrap().as_mut();

        let warm = pcfg.warm_start && state.is_some();
        let mut global = match (&state, warm) {
            (Some(s), true) => {
                cl.reseed(&s.nodes, params)?;
                s.global.clone()
            }
            _ => GlobalState::new(dim),
        };
        let res = admm::solve_from_with(cl, &mut global, &pc, Some(ds), opts, &mut scratch)?;

        let ledger = res.transfers.clone();
        let objective =
            admm::solver::objective_with(ds, loss.as_ref(), pc.solver.gamma, &res.x, &mut scratch);
        completed.push(PathPointRecord {
            kappa: pt.kappa,
            rho_c: pt.rho_c,
            rho_b: pt.rho_b,
            warm,
            iters: res.iters,
            converged: res.converged,
            objective,
            support: res.support.clone(),
            wall_seconds: watch.elapsed_secs(),
            gram_builds: ledger.gram_builds.saturating_sub(prev_ledger.gram_builds),
            chol_factorizations: ledger
                .chol_factorizations
                .saturating_sub(prev_ledger.chol_factorizations),
            chol_reuses: ledger.chol_reuses.saturating_sub(prev_ledger.chol_reuses),
        });
        prev_ledger = ledger;

        // the ONLY state transfer between points: capture the serializable
        // snapshot (also what the checkpoint persists, so resume sees
        // exactly what an uninterrupted run would)
        state = if pcfg.warm_start {
            Some(SolverState::capture(cl, &global)?)
        } else {
            None
        };
        if let Some(ck_path) = &pcfg.checkpoint {
            // a degraded (async) cluster can export fewer states than the
            // full roster; such a partial snapshot could never re-seed the
            // fresh full cluster a resume builds, so persist it only when
            // it covers every node — a resume from a degraded sweep then
            // cold-starts its next point instead of failing on reseed
            let complete = match &state {
                None => true,
                Some(s) => {
                    s.nodes.len() == ds.nodes()
                        && (0..ds.nodes()).all(|i| s.nodes.iter().any(|w| w.node == i))
                }
            };
            checkpoint::save(
                std::path::Path::new(ck_path),
                &checkpoint::Checkpoint {
                    problem_hash: hash,
                    completed: completed.clone(),
                    state: if complete { state.clone() } else { None },
                },
            )?;
        }
        final_result = Some(res);
    }

    Ok(PathOutcome {
        trace: PathTrace { points: completed },
        final_result,
        resumed_points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_bad_sweeps() {
        let mut p = PathConfig::default();
        assert!(p.validate().is_err(), "empty budgets");
        p.budgets = vec![10, 10];
        assert!(p.validate().is_err(), "non-descending");
        p.budgets = vec![10, 20];
        assert!(p.validate().is_err(), "ascending");
        p.budgets = vec![10, 5, 0];
        assert!(p.validate().is_err(), "zero budget");
        p.budgets = vec![10, 5, 2];
        p.validate().unwrap();
        p.rho_ladder = vec![1.0, -2.0];
        assert!(p.validate().is_err(), "negative rho");
        p.rho_ladder = vec![2.0, 1.0];
        p.validate().unwrap();
        p.limit = Some(0);
        assert!(p.validate().is_err(), "zero limit");
    }

    #[test]
    fn points_cross_ladder_with_budgets_preserving_alpha() {
        let mut pcfg = PathConfig::default();
        pcfg.budgets = vec![8, 4];
        pcfg.rho_ladder = vec![2.0, 0.5];
        let base = SolverConfig {
            rho_c: 1.0,
            rho_b: 0.5, // alpha = 0.5
            ..Default::default()
        };
        let pts = pcfg.points(&base);
        assert_eq!(pts.len(), 4);
        assert_eq!(pts[0], PathPoint { kappa: 8, rho_c: 2.0, rho_b: 1.0 });
        assert_eq!(pts[1], PathPoint { kappa: 4, rho_c: 2.0, rho_b: 1.0 });
        assert_eq!(pts[2], PathPoint { kappa: 8, rho_c: 0.5, rho_b: 0.25 });
        assert_eq!(pts[3], PathPoint { kappa: 4, rho_c: 0.5, rho_b: 0.25 });
    }

    #[test]
    fn points_default_to_base_rho_without_ladder() {
        let mut pcfg = PathConfig::default();
        pcfg.budgets = vec![6, 3];
        let base = SolverConfig::default();
        let pts = pcfg.points(&base);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].rho_c, base.rho_c);
        assert_eq!(pts[0].rho_b, base.rho_b);
    }

    #[test]
    fn trace_csv_shape_and_totals() {
        let mut t = PathTrace::default();
        t.points.push(PathPointRecord {
            kappa: 8,
            iters: 10,
            support: vec![0, 2],
            ..Default::default()
        });
        t.points.push(PathPointRecord {
            kappa: 4,
            iters: 3,
            warm: true,
            ..Default::default()
        });
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().starts_with("8,"));
        assert!(csv.lines().nth(2).unwrap().contains(",true,"));
        assert_eq!(t.total_iters(), 13);
        assert_eq!(t.last().unwrap().kappa, 4);
    }
}

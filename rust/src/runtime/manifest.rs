//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime: tile shapes, iteration constants, and the
//! input/output specs of every artifact.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element type name (e.g. "float32").
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled artifact: its HLO file and I/O contract.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// HLO text file name inside the artifact directory.
    pub file: String,
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

/// The whole `manifest.json`: shared shape constants + artifact table.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Hash of the compile settings that produced the artifacts.
    pub fingerprint: String,
    /// Row-tile height the artifacts were compiled for.
    pub tile_m: usize,
    /// Feature-block width (columns) baked into the artifacts.
    pub block_n: usize,
    /// Pallas tile rows within a block program.
    pub bm: usize,
    /// CG iterations baked into the block-solve artifact.
    pub cg_iters: usize,
    /// Newton iterations baked into the omega artifacts.
    pub newton_iters: usize,
    /// Class count the softmax artifacts were compiled for.
    pub classes: usize,
    /// Algorithm-2 sweeps baked into each `node_sweep_*` artifact.
    pub inner_sweeps: usize,
    /// Lowering mode of the tile programs ("xla" or "pallas").
    pub mode: String,
    /// Length of the shared scalar-parameter vector.
    pub param_size: usize,
    /// Artifact table keyed by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Read + parse `manifest.json`.
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            anyhow::anyhow!(
                "reading {} (run `make artifacts` first?): {e}",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let v = Json::parse(text)?;
        let usize_of = |key: &str| -> anyhow::Result<usize> {
            v.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest key `{key}` must be an integer"))
        };
        let mut artifacts = BTreeMap::new();
        let arts = v
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("`artifacts` must be an object"))?;
        for (name, spec) in arts {
            let tensor_list = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                spec.req(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("{name}.{key} must be an array"))?
                    .iter()
                    .map(|t| {
                        let shape = t
                            .req("shape")?
                            .as_arr()
                            .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?
                            .iter()
                            .map(|d| {
                                d.as_usize()
                                    .ok_or_else(|| anyhow::anyhow!("bad dim in {name}.{key}"))
                            })
                            .collect::<anyhow::Result<Vec<usize>>>()?;
                        let dtype = t
                            .req("dtype")?
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("bad dtype"))?
                            .to_string();
                        anyhow::ensure!(dtype == "float32", "only f32 artifacts supported");
                        Ok(TensorSpec { shape, dtype })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: spec
                        .req("file")?
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("{name}.file must be a string"))?
                        .to_string(),
                    inputs: tensor_list("inputs")?,
                    outputs: tensor_list("outputs")?,
                },
            );
        }
        Ok(Manifest {
            fingerprint: v
                .req("fingerprint")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            tile_m: usize_of("tile_m")?,
            block_n: usize_of("block_n")?,
            bm: usize_of("bm")?,
            cg_iters: usize_of("cg_iters")?,
            newton_iters: usize_of("newton_iters")?,
            classes: usize_of("classes")?,
            inner_sweeps: v
                .get("inner_sweeps")
                .and_then(|x| x.as_usize())
                .unwrap_or(3),
            mode: v
                .get("mode")
                .and_then(|x| x.as_str())
                .unwrap_or("xla")
                .to_string(),
            param_size: v
                .req("param_slots")?
                .req("size")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("param_slots.size"))?,
            artifacts,
        })
    }

    /// The omega artifact name for a loss.
    pub fn omega_artifact(kind: crate::losses::LossKind) -> &'static str {
        match kind {
            crate::losses::LossKind::Squared => "omega_squared",
            crate::losses::LossKind::Logistic => "omega_logistic",
            crate::losses::LossKind::Hinge => "omega_hinge",
            crate::losses::LossKind::Softmax => "omega_softmax",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "fingerprint": "abc123",
      "tile_m": 128, "block_n": 64, "bm": 32,
      "cg_iters": 24, "newton_iters": 8, "classes": 10,
      "param_slots": {"m_blocks": 0, "rho_l": 1, "rho_c": 2, "reg": 3, "size": 8},
      "artifacts": {
        "gram_tile": {
          "file": "gram_tile.hlo.txt",
          "inputs": [{"shape": [128, 64], "dtype": "float32"}],
          "outputs": [{"shape": [64, 64], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.tile_m, 128);
        assert_eq!(m.block_n, 64);
        assert_eq!(m.cg_iters, 24);
        assert_eq!(m.param_size, 8);
        let g = &m.artifacts["gram_tile"];
        assert_eq!(g.file, "gram_tile.hlo.txt");
        assert_eq!(g.inputs[0].shape, vec![128, 64]);
        assert_eq!(g.outputs[0].elems(), 64 * 64);
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("float32", "float64");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_keys() {
        assert!(Manifest::parse(r#"{"tile_m": 1}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // integration-ish: if `make artifacts` has run, the real manifest
        // must parse and contain every program the backend needs.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let m = Manifest::load(&path).unwrap();
        for name in [
            "gram_tile",
            "matvec_tile",
            "matvec_t_tile",
            "block_solve",
            "block_iteration",
            "omega_squared",
            "omega_logistic",
            "omega_hinge",
            "omega_softmax",
        ] {
            assert!(m.artifacts.contains_key(name), "missing {name}");
        }
    }
}

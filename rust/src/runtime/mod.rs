//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax >= 0.5's
//! 64-bit-id protos; the text parser reassigns ids).  Executables are
//! compiled lazily on first use and cached; input tensors that live across
//! iterations (feature tiles, Gram matrices, labels) are staged once as
//! persistent `PjRtBuffer`s — the analogue of the paper keeping `A_ij`
//! resident on GPU j — while per-iteration vectors go through the
//! transfer-ledger-accounted staging path.

/// The `manifest.json` contract with the AOT compiler.
pub mod manifest;
/// The shared scalar-parameter device buffer.
pub mod params;

pub use manifest::{ArtifactSpec, Manifest};
pub use params::ParamsBuffer;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::Stopwatch;

/// PJRT client + lazily compiled executable cache.
///
/// Ownership note: the `xla` wrapper types refcount the client with `Rc`,
/// so an `XlaRuntime` (and every buffer/executable derived from it) must
/// stay on a single thread.  The architecture therefore gives **each node
/// worker its own private runtime** — created before the worker moves to
/// its thread, after which the entire object graph lives there.  That is
/// also the honest simulation: in the paper each node owns its own GPU
/// context.  `backend::xla::XlaBackend` carries the `unsafe impl Send`
/// with this invariant documented.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

/// A persistent device-resident tensor.
pub struct DeviceTensor {
    /// The device-resident PJRT buffer.
    pub buffer: xla::PjRtBuffer,
    /// Element count (f32).
    pub elems: usize,
}

impl XlaRuntime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> anyhow::Result<XlaRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(XlaRuntime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The manifest the artifacts were compiled against.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(
        &self,
        name: &str,
    ) -> anyhow::Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact `{name}`"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::rc::Rc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Stage a host f32 tensor as a persistent device buffer.
    /// Returns the tensor and the staging wall-time in seconds.
    pub fn stage(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<(DeviceTensor, f64)> {
        let watch = Stopwatch::start();
        let buffer = self
            .client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow::anyhow!("staging {dims:?}: {e:?}"))?;
        let secs = watch.elapsed_secs();
        Ok((
            DeviceTensor {
                buffer,
                elems: data.len(),
            },
            secs,
        ))
    }

    /// Execute an artifact over device buffers; returns the raw output
    /// buffers of the (single) replica.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<xla::PjRtBuffer>> {
        let mut out = exe
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "no outputs");
        Ok(out.swap_remove(0))
    }

    /// Pull a tuple output buffer back to host f32 vectors.
    /// Returns the vectors and the copy-out wall time.
    pub fn fetch_tuple(&self, buffer: &xla::PjRtBuffer) -> anyhow::Result<(Vec<Vec<f32>>, f64)> {
        let watch = Stopwatch::start();
        let literal = buffer
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(
                p.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?,
            );
        }
        let secs = watch.elapsed_secs();
        Ok((out, secs))
    }
}

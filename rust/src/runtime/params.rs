//! The (8, 1) f32 scalar-parameter vector shared by every artifact —
//! mirrors `python/compile/model.py` slot layout (P_MBLOCKS..P_REG).

use super::{DeviceTensor, XlaRuntime};
use crate::backend::BlockParams;

/// Slot of the block count M.
pub const P_MBLOCKS: usize = 0;
/// Slot of the inner penalty rho_l.
pub const P_RHO_L: usize = 1;
/// Slot of the consensus penalty rho_c.
pub const P_RHO_C: usize = 2;
/// Slot of the block curvature reg.
pub const P_REG: usize = 3;

/// Device-resident parameter vector, re-staged only when values change.
pub struct ParamsBuffer {
    tensor: Option<DeviceTensor>,
    current: Option<(f64, BlockParams)>,
    size: usize,
}

impl ParamsBuffer {
    /// Empty buffer of `size` scalar slots.
    pub fn new(size: usize) -> ParamsBuffer {
        ParamsBuffer {
            tensor: None,
            current: None,
            size,
        }
    }

    /// Host-side encoding (exposed for tests).
    pub fn encode(m_blocks: f64, p: BlockParams, size: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; size];
        v[P_MBLOCKS] = m_blocks as f32;
        v[P_RHO_L] = p.rho_l as f32;
        v[P_RHO_C] = p.rho_c as f32;
        v[P_REG] = p.reg as f32;
        v
    }

    /// Get the device buffer for these parameter values, staging if needed.
    /// Returns the buffer and the bytes staged (0 when cached).
    pub fn get(
        &mut self,
        rt: &XlaRuntime,
        m_blocks: f64,
        p: BlockParams,
    ) -> anyhow::Result<(&DeviceTensor, usize, f64)> {
        let key = (m_blocks, p);
        if self.current != Some(key) || self.tensor.is_none() {
            let host = Self::encode(m_blocks, p, self.size);
            let (tensor, secs) = rt.stage(&host, &[self.size, 1])?;
            self.tensor = Some(tensor);
            self.current = Some(key);
            return Ok((self.tensor.as_ref().unwrap(), self.size * 4, secs));
        }
        Ok((self.tensor.as_ref().unwrap(), 0, 0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_layout_matches_python_slots() {
        let p = BlockParams {
            rho_l: 2.0,
            rho_c: 1.5,
            reg: 1.525,
        };
        let v = ParamsBuffer::encode(4.0, p, 8);
        assert_eq!(v.len(), 8);
        assert_eq!(v[P_MBLOCKS], 4.0);
        assert_eq!(v[P_RHO_L], 2.0);
        assert_eq!(v[P_RHO_C], 1.5);
        assert_eq!(v[P_REG], 1.525);
        assert_eq!(&v[4..], &[0.0; 4]);
    }
}

//! Client for a running `psfit serve` daemon: submit jobs, poll status,
//! request predictions.  The CLI's `psfit submit` / `psfit predict` /
//! `psfit jobs` subcommands and the integration tests all go through
//! here.

use std::time::{Duration, Instant};

use crate::network::socket::wire::{self, JobSpec, JobStatus, JobSummary, WireCommand};
use crate::network::socket::{connect, Endpoint, SocketStream};
use crate::serve::JobPhase;

/// A connected `psfit serve` client session.
pub struct ServeClient {
    stream: SocketStream,
}

impl ServeClient {
    /// Connect with defaults: 3 s connect timeout, 3 retries, 120 s read
    /// timeout (submissions reply instantly; only `wait` polls).
    pub fn connect(addr: &str) -> anyhow::Result<ServeClient> {
        ServeClient::connect_with(addr, Duration::from_secs(3), Some(Duration::from_secs(120)), 3)
    }

    /// [`ServeClient::connect`] with explicit timeouts and retry count.
    pub fn connect_with(
        addr: &str,
        connect_timeout: Duration,
        read_timeout: Option<Duration>,
        retries: u32,
    ) -> anyhow::Result<ServeClient> {
        let mut stream = connect(&Endpoint::parse(addr), connect_timeout, retries)?;
        stream.set_read_timeout(read_timeout)?;
        wire::client_handshake(&mut stream)?;
        Ok(ServeClient { stream })
    }

    /// One request/reply exchange.  An `Error` reply or a closed
    /// connection is an error here.
    fn call(&mut self, cmd: &WireCommand) -> anyhow::Result<WireCommand> {
        wire::write_frame(&mut self.stream, cmd)?;
        match wire::read_frame(&mut self.stream)? {
            Some((WireCommand::Error { message }, _)) => anyhow::bail!("serve: {message}"),
            Some((reply, _)) => Ok(reply),
            None => anyhow::bail!("serve closed the connection"),
        }
    }

    /// Submit a fit job; returns its job id immediately (the fit runs in
    /// the daemon, poll with [`ServeClient::status`] or
    /// [`ServeClient::wait`]).
    pub fn submit(&mut self, name: &str, spec: JobSpec) -> anyhow::Result<u64> {
        let cmd = WireCommand::Submit {
            name: name.to_string(),
            spec,
        };
        match self.call(&cmd)? {
            WireCommand::Submitted { job } => Ok(job),
            other => anyhow::bail!("unexpected `{}` to submit", other.name()),
        }
    }

    /// Poll one job's status.
    pub fn status(&mut self, job: u64) -> anyhow::Result<JobStatus> {
        match self.call(&WireCommand::Status { job })? {
            WireCommand::StatusReply(st) => Ok(*st),
            other => anyhow::bail!("unexpected `{}` to status", other.name()),
        }
    }

    /// Poll until the job finishes (done, timed out, or failed) or
    /// `timeout` elapses.  A timed-out job is a terminal *success* here —
    /// its best-so-far model is queryable; check the returned phase.  A
    /// failed job is an error carrying the daemon's failure message.
    pub fn wait(&mut self, job: u64, timeout: Duration) -> anyhow::Result<JobStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            let st = self.status(job)?;
            match JobPhase::from_code(st.phase)? {
                JobPhase::Done | JobPhase::TimedOut => return Ok(st),
                JobPhase::Failed => {
                    anyhow::bail!("job {job} failed: {}", st.message)
                }
                JobPhase::Queued | JobPhase::Running => {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "job {job} still {} after {timeout:?}",
                        JobPhase::from_code(st.phase)?.name()
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Score a sparse feature vector against a finished job's model;
    /// returns one value per class.  Non-finite feature values are
    /// rejected client-side — a NaN query would otherwise come back as a
    /// NaN score with no hint of which input caused it.
    pub fn predict(&mut self, job: u64, features: &[(u32, f64)]) -> anyhow::Result<Vec<f64>> {
        for &(idx, v) in features {
            anyhow::ensure!(
                v.is_finite(),
                "predict: non-finite value {v} for feature {idx}; \
                 queries must be finite"
            );
        }
        let cmd = WireCommand::Predict {
            job,
            features: features.to_vec(),
        };
        match self.call(&cmd)? {
            WireCommand::PredictReply { values } => Ok(values),
            other => anyhow::bail!("unexpected `{}` to predict", other.name()),
        }
    }

    /// List every job the daemon knows, id ascending.
    pub fn jobs(&mut self) -> anyhow::Result<Vec<JobSummary>> {
        match self.call(&WireCommand::Jobs)? {
            WireCommand::JobsReply { jobs } => Ok(jobs),
            other => anyhow::bail!("unexpected `{}` to jobs", other.name()),
        }
    }
}

//! Client for a running `psfit serve` daemon: submit jobs, poll status,
//! request predictions.  The CLI's `psfit submit` / `psfit predict` /
//! `psfit jobs` subcommands and the integration tests all go through
//! here.
//!
//! The client **rides through coordinator restarts**: a refused connect,
//! a reset stream, or a mid-exchange close tears the session down and
//! re-dials with the shared seeded [`crate::util::backoff`] policy, up to
//! a bounded attempt budget.  Application-level replies (`Error`,
//! `Rejected`) are terminal — a draining daemon's refusal must fail fast,
//! not be retried into the restarted daemon.

use std::time::{Duration, Instant};

use crate::network::socket::wire::{self, JobSpec, JobStatus, JobSummary, WireCommand};
use crate::network::socket::{connect, connect_backoff_seed, Endpoint, SocketStream};
use crate::serve::JobPhase;
use crate::util::backoff::{self, Backoff};

/// Reconnect attempts per call before giving up (with the 100 ms-base,
/// 2 s-cap backoff this spans roughly half a minute — enough for a
/// coordinator restart, bounded enough to fail a dead one).
const MAX_RECONNECTS: u32 = 20;

/// A `psfit serve` client session that transparently re-dials the daemon.
pub struct ServeClient {
    addr: String,
    connect_timeout: Duration,
    read_timeout: Option<Duration>,
    retries: u32,
    stream: Option<SocketStream>,
    backoff: Backoff,
    reconnects: u64,
}

impl ServeClient {
    /// Connect with defaults: 3 s connect timeout, 3 retries, 120 s read
    /// timeout (submissions reply instantly; only `wait` polls).
    pub fn connect(addr: &str) -> anyhow::Result<ServeClient> {
        ServeClient::connect_with(addr, Duration::from_secs(3), Some(Duration::from_secs(120)), 3)
    }

    /// [`ServeClient::connect`] with explicit timeouts and retry count.
    pub fn connect_with(
        addr: &str,
        connect_timeout: Duration,
        read_timeout: Option<Duration>,
        retries: u32,
    ) -> anyhow::Result<ServeClient> {
        let mut client = ServeClient {
            addr: addr.to_string(),
            connect_timeout,
            read_timeout,
            retries,
            stream: None,
            // per-address seed: many clients hammering one restarting
            // daemon fan their re-dials apart deterministically
            backoff: Backoff::new(
                Duration::from_millis(100),
                Duration::from_millis(2000),
                connect_backoff_seed(&Endpoint::parse(addr)),
            ),
            reconnects: 0,
        };
        client.stream = Some(client.dial()?);
        Ok(client)
    }

    /// How many times this session re-dialed the daemon after the initial
    /// connect — the CLI surfaces this so a restart the client rode
    /// through is visible, not silent.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// One connection attempt: dial, apply the read timeout, handshake.
    fn dial(&self) -> anyhow::Result<SocketStream> {
        let mut stream = connect(&Endpoint::parse(&self.addr), self.connect_timeout, self.retries)?;
        stream.set_read_timeout(self.read_timeout)?;
        wire::client_handshake(&mut stream)?;
        Ok(stream)
    }

    /// One request/reply exchange, re-dialing through transport failures.
    /// An `Error` or `Rejected` reply is a terminal error here — the
    /// daemon answered, it just said no.
    fn call(&mut self, cmd: &WireCommand) -> anyhow::Result<WireCommand> {
        let mut attempts = 0u32;
        let mut last_err = String::new();
        loop {
            if self.stream.is_none() {
                match self.dial() {
                    Ok(s) => {
                        self.stream = Some(s);
                        self.reconnects += 1;
                    }
                    Err(e) => {
                        last_err = e.to_string();
                        attempts += 1;
                        anyhow::ensure!(
                            attempts < MAX_RECONNECTS,
                            "serve {} unreachable after {attempts} reconnect attempt(s): {last_err}",
                            self.addr
                        );
                        backoff::sleep_next(&mut self.backoff);
                        continue;
                    }
                }
            }
            let stream = self.stream.as_mut().expect("stream present");
            let exchange = wire::write_frame(stream, cmd).and_then(|_| wire::read_frame(stream));
            match exchange {
                Ok(Some((WireCommand::Error { message }, _))) => {
                    anyhow::bail!("serve: {message}")
                }
                Ok(Some((WireCommand::Rejected { reason }, _))) => {
                    anyhow::bail!("serve rejected the request: {reason}")
                }
                Ok(Some((reply, _))) => {
                    self.backoff.reset();
                    return Ok(reply);
                }
                Ok(None) => last_err = "serve closed the connection".to_string(),
                Err(e) => last_err = e.to_string(),
            }
            // connection died (daemon restarting, socket reset): drop the
            // session and re-dial with backoff
            if let Some(s) = self.stream.take() {
                s.shutdown();
            }
            attempts += 1;
            anyhow::ensure!(
                attempts < MAX_RECONNECTS,
                "serve connection to {} lost after {attempts} attempt(s): {last_err}",
                self.addr
            );
            backoff::sleep_next(&mut self.backoff);
        }
    }

    /// Submit a fit job; returns its job id immediately (the fit runs in
    /// the daemon, poll with [`ServeClient::status`] or
    /// [`ServeClient::wait`]).  Note the at-least-once caveat: if the
    /// daemon dies between accepting the submit and replying, the
    /// transparent re-dial re-sends it and the job may run twice (the
    /// journal makes any duplicate visible in `psfit jobs`).
    pub fn submit(&mut self, name: &str, spec: JobSpec) -> anyhow::Result<u64> {
        let cmd = WireCommand::Submit {
            name: name.to_string(),
            spec,
        };
        match self.call(&cmd)? {
            WireCommand::Submitted { job } => Ok(job),
            other => anyhow::bail!("unexpected `{}` to submit", other.name()),
        }
    }

    /// Poll one job's status.
    pub fn status(&mut self, job: u64) -> anyhow::Result<JobStatus> {
        match self.call(&WireCommand::Status { job })? {
            WireCommand::StatusReply(st) => Ok(*st),
            other => anyhow::bail!("unexpected `{}` to status", other.name()),
        }
    }

    /// Poll until the job finishes (done, timed out, or failed) or
    /// `timeout` elapses.  A timed-out job is a terminal *success* here —
    /// its best-so-far model is queryable; check the returned phase.  A
    /// failed job is an error carrying the daemon's failure message.
    pub fn wait(&mut self, job: u64, timeout: Duration) -> anyhow::Result<JobStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            let st = self.status(job)?;
            match JobPhase::from_code(st.phase)? {
                JobPhase::Done | JobPhase::TimedOut => return Ok(st),
                JobPhase::Failed => {
                    anyhow::bail!("job {job} failed: {}", st.message)
                }
                JobPhase::Queued | JobPhase::Running => {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "job {job} still {} after {timeout:?}",
                        JobPhase::from_code(st.phase)?.name()
                    );
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Score a sparse feature vector against a finished job's model;
    /// returns one value per class.  Non-finite feature values are
    /// rejected client-side — a NaN query would otherwise come back as a
    /// NaN score with no hint of which input caused it.
    pub fn predict(&mut self, job: u64, features: &[(u32, f64)]) -> anyhow::Result<Vec<f64>> {
        for &(idx, v) in features {
            anyhow::ensure!(
                v.is_finite(),
                "predict: non-finite value {v} for feature {idx}; \
                 queries must be finite"
            );
        }
        let cmd = WireCommand::Predict {
            job,
            features: features.to_vec(),
        };
        match self.call(&cmd)? {
            WireCommand::PredictReply { values } => Ok(values),
            other => anyhow::bail!("unexpected `{}` to predict", other.name()),
        }
    }

    /// List every job the daemon knows, id ascending.
    pub fn jobs(&mut self) -> anyhow::Result<Vec<JobSummary>> {
        match self.call(&WireCommand::Jobs)? {
            WireCommand::JobsReply { jobs } => Ok(jobs),
            other => anyhow::bail!("unexpected `{}` to jobs", other.name()),
        }
    }
}

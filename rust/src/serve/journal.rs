//! PSJ1: the append-only job journal behind `psfit serve --state-dir`.
//!
//! The daemon's job table is rebuilt from this file on startup, so a
//! coordinator crash (or a deliberate drain) loses no job metadata and no
//! fitted model.  The format follows the PSC1/PSF1 family: a magic +
//! version header, then a sequence of records
//!
//! ```text
//! | u32 payload_len (LE) | payload bytes | u64 FNV-1a(payload) (LE) |
//! ```
//!
//! where the payload's first byte is the record tag:
//!
//! | tag | record            | payload fields                           |
//! |-----|-------------------|------------------------------------------|
//! | 1   | job submitted     | job id, name, full `JobSpec`             |
//! | 2   | phase transition  | job id, phase, converged, iters, objective, wall, message |
//! | 3   | model artifact    | job id, blob filename, blob FNV-1a       |
//! | 4   | clean shutdown    | (empty) — written by a completed drain   |
//!
//! Model artifacts are separate `model-<job>.psm` blobs written via
//! tmp + rename *before* their journal record, so a record never points at
//! a half-written blob.  Replay distinguishes two failure shapes: a
//! **truncated tail** (the process died mid-append; every complete record
//! is kept, the ragged bytes are dropped, and appending resumes at the
//! last valid boundary) and a **corrupted record** (checksum or structure
//! damage in the middle of the log; a named `JournalCorrupt` error, never
//! a silently wrong job table).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::network::socket::wire::JobSpec;
use crate::serve::model::FittedModel;
use crate::serve::JobPhase;
use crate::util::fnv1a;

/// Journal file magic.
pub const JOURNAL_MAGIC: &[u8; 4] = b"PSJ1";
/// Journal format version.
pub const JOURNAL_VERSION: u32 = 1;
/// Journal filename inside the state directory.
pub const JOURNAL_FILE: &str = "serve.journal";
/// Upper bound on one record's payload — journal records are tiny (the
/// largest carries a config JSON string), so anything bigger is damage.
const MAX_RECORD: usize = 1 << 26;

const REC_SUBMIT: u8 = 1;
const REC_PHASE: u8 = 2;
const REC_MODEL: u8 = 3;
const REC_DRAIN: u8 = 4;

/// Path of job `job`'s model artifact inside `dir`.
pub fn model_blob_path(dir: &Path, job: u64) -> PathBuf {
    dir.join(format!("model-{job}.psm"))
}

/// Path of job `job`'s auto-written mid-fit PSF1 checkpoint inside `dir`.
pub fn checkpoint_path(dir: &Path, job: u64) -> PathBuf {
    dir.join(format!("job-{job}.psf"))
}

/// One job as reconstructed by replay.
#[derive(Debug, Clone)]
pub struct ReplayedJob {
    /// Client-supplied display name.
    pub name: String,
    /// The submitted problem + config description.
    pub spec: JobSpec,
    /// Last journaled lifecycle phase.
    pub phase: JobPhase,
    /// Whether the solver hit its tolerances.
    pub converged: bool,
    /// Outer iterations run.
    pub iters: u64,
    /// Regularized objective at the fitted point.
    pub objective: f64,
    /// Solve wall time in seconds.
    pub wall_seconds: f64,
    /// Failure message when the phase is `Failed`, else empty.
    pub message: String,
    /// The fitted model, when a valid artifact record + blob exist.
    pub model: Option<FittedModel>,
}

/// The result of replaying a journal.
#[derive(Debug)]
pub struct Replay {
    /// Every journaled job, id ascending, in its last journaled state.
    pub jobs: BTreeMap<u64, ReplayedJob>,
    /// `true` iff the journal ends with a clean-shutdown marker — the
    /// previous daemon drained; anything else means it crashed.
    pub clean_shutdown: bool,
    /// Complete records replayed.
    pub records: usize,
    /// `true` when a ragged tail (torn final append) was dropped.
    pub truncated: bool,
    /// Non-fatal replay problems (e.g. an unreadable model blob whose job
    /// will simply be re-run).
    pub warnings: Vec<String>,
}

/// An open journal: replayed once at startup, then append-only.
#[derive(Debug)]
pub struct Journal {
    file: File,
    dir: PathBuf,
}

impl Journal {
    /// Open (or create) the journal inside `dir`, replay it, drop any
    /// torn tail, and position for appending.  `dir` is created if
    /// missing.  A corrupted record is a hard error — restoring a wrong
    /// job table would be worse than refusing to start.
    pub fn open(dir: &Path) -> anyhow::Result<(Journal, Replay)> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create state dir {}: {e}", dir.display()))?;
        let path = dir.join(JOURNAL_FILE);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("cannot open journal {}: {e}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        if bytes.is_empty() {
            file.write_all(JOURNAL_MAGIC)?;
            file.write_all(&JOURNAL_VERSION.to_le_bytes())?;
            let replay = Replay {
                jobs: BTreeMap::new(),
                clean_shutdown: true,
                records: 0,
                truncated: false,
                warnings: Vec::new(),
            };
            return Ok((
                Journal {
                    file,
                    dir: dir.to_path_buf(),
                },
                replay,
            ));
        }
        let (replay, valid_end) = replay_bytes(&bytes, dir)?;
        if replay.truncated {
            // drop the torn tail so new appends start at a record boundary
            file.set_len(valid_end as u64)?;
        }
        file.seek(SeekFrom::Start(valid_end as u64))?;
        Ok((
            Journal {
                file,
                dir: dir.to_path_buf(),
            },
            replay,
        ))
    }

    /// The state directory this journal lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Journal a job submission.
    pub fn record_submit(&mut self, job: u64, name: &str, spec: &JobSpec) -> anyhow::Result<()> {
        let mut p = Vec::new();
        p.push(REC_SUBMIT);
        w_u64(&mut p, job);
        w_str(&mut p, name);
        w_u32(&mut p, spec.n);
        w_u32(&mut p, spec.m);
        w_u32(&mut p, spec.nodes);
        w_f64(&mut p, spec.sparsity);
        w_f64(&mut p, spec.density);
        w_f64(&mut p, spec.noise_std);
        w_u64(&mut p, spec.seed);
        w_u32(&mut p, spec.kappa);
        w_str(&mut p, &spec.config);
        self.append(&p)
    }

    /// Journal a phase transition with the stats known at that point.
    #[allow(clippy::too_many_arguments)]
    pub fn record_phase(
        &mut self,
        job: u64,
        phase: JobPhase,
        converged: bool,
        iters: u64,
        objective: f64,
        wall_seconds: f64,
        message: &str,
    ) -> anyhow::Result<()> {
        let mut p = Vec::new();
        p.push(REC_PHASE);
        w_u64(&mut p, job);
        p.push(phase.code());
        p.push(converged as u8);
        w_u64(&mut p, iters);
        w_u64(&mut p, objective.to_bits());
        w_u64(&mut p, wall_seconds.to_bits());
        w_str(&mut p, message);
        self.append(&p)
    }

    /// Persist a fitted model: write the PSM1 blob atomically (tmp +
    /// rename), then journal the artifact record pointing at it.
    pub fn record_model(&mut self, job: u64, model: &FittedModel) -> anyhow::Result<()> {
        let blob = model.to_bytes();
        let path = model_blob_path(&self.dir, job);
        let tmp = path.with_extension("psm.tmp");
        std::fs::write(&tmp, &blob)
            .map_err(|e| anyhow::anyhow!("cannot write model blob {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| anyhow::anyhow!("cannot finalize model blob {}: {e}", path.display()))?;
        let name = path
            .file_name()
            .map(|f| f.to_string_lossy().into_owned())
            .unwrap_or_default();
        let mut p = Vec::new();
        p.push(REC_MODEL);
        w_u64(&mut p, job);
        w_str(&mut p, &name);
        w_u64(&mut p, fnv1a(&blob));
        self.append(&p)
    }

    /// Journal the clean-shutdown marker a completed drain writes last.
    pub fn record_drain(&mut self) -> anyhow::Result<()> {
        self.append(&[REC_DRAIN])
    }

    fn append(&mut self, payload: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(
            !payload.is_empty() && payload.len() <= MAX_RECORD,
            "journal record payload of {} byte(s) out of range",
            payload.len()
        );
        // one contiguous write per record keeps a torn append a pure
        // prefix, which replay then drops as a truncated tail
        let mut rec = Vec::with_capacity(payload.len() + 12);
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(payload);
        rec.extend_from_slice(&fnv1a(payload).to_le_bytes());
        self.file.write_all(&rec)?;
        Ok(())
    }
}

/// Replay journal bytes (header included); returns the reconstructed
/// state and the offset just past the last complete record.
fn replay_bytes(bytes: &[u8], dir: &Path) -> anyhow::Result<(Replay, usize)> {
    anyhow::ensure!(
        bytes.len() >= 8 && &bytes[..4] == JOURNAL_MAGIC,
        "JournalCorrupt: {} is not a PSJ1 journal",
        dir.join(JOURNAL_FILE).display()
    );
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    anyhow::ensure!(
        version == JOURNAL_VERSION,
        "unsupported journal version {version} (this build speaks v{JOURNAL_VERSION})"
    );
    let mut replay = Replay {
        jobs: BTreeMap::new(),
        clean_shutdown: false,
        records: 0,
        truncated: false,
        warnings: Vec::new(),
    };
    let mut pos = 8usize;
    let mut clean = true; // an empty journal counts as cleanly shut down
    loop {
        if pos == bytes.len() {
            break;
        }
        if bytes.len() - pos < 4 {
            replay.truncated = true;
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        if len == 0 || len > MAX_RECORD {
            anyhow::bail!(
                "JournalCorrupt: record {} has absurd length {len}",
                replay.records
            );
        }
        if bytes.len() - pos < 4 + len + 8 {
            replay.truncated = true;
            break;
        }
        let payload = &bytes[pos + 4..pos + 4 + len];
        let stored =
            u64::from_le_bytes(bytes[pos + 4 + len..pos + 4 + len + 8].try_into().unwrap());
        let actual = fnv1a(payload);
        anyhow::ensure!(
            stored == actual,
            "JournalCorrupt: record {} checksum mismatch (stored {stored:#018x}, computed {actual:#018x})",
            replay.records
        );
        apply_record(payload, dir, &mut replay, &mut clean).map_err(|e| {
            anyhow::anyhow!("JournalCorrupt: record {} undecodable: {e}", replay.records)
        })?;
        replay.records += 1;
        pos += 4 + len + 8;
    }
    replay.clean_shutdown = clean;
    Ok((replay, pos))
}

fn apply_record(
    payload: &[u8],
    dir: &Path,
    replay: &mut Replay,
    clean: &mut bool,
) -> anyhow::Result<()> {
    let mut c = Rd { buf: payload, pos: 0 };
    let tag = c.u8()?;
    match tag {
        REC_SUBMIT => {
            *clean = false;
            let job = c.u64()?;
            let name = c.str()?;
            let spec = JobSpec {
                n: c.u32()?,
                m: c.u32()?,
                nodes: c.u32()?,
                sparsity: c.f64()?,
                density: c.f64()?,
                noise_std: c.f64()?,
                seed: c.u64()?,
                kappa: c.u32()?,
                config: c.str()?,
            };
            replay.jobs.insert(
                job,
                ReplayedJob {
                    name,
                    spec,
                    phase: JobPhase::Queued,
                    converged: false,
                    iters: 0,
                    objective: f64::NAN,
                    wall_seconds: 0.0,
                    message: String::new(),
                    model: None,
                },
            );
        }
        REC_PHASE => {
            *clean = false;
            let job = c.u64()?;
            let phase = JobPhase::from_code(c.u8()?)?;
            let converged = c.u8()? != 0;
            let iters = c.u64()?;
            let objective = f64::from_bits(c.u64()?);
            let wall_seconds = f64::from_bits(c.u64()?);
            let message = c.str()?;
            match replay.jobs.get_mut(&job) {
                Some(e) => {
                    e.phase = phase;
                    e.converged = converged;
                    e.iters = iters;
                    e.objective = objective;
                    e.wall_seconds = wall_seconds;
                    e.message = message;
                }
                None => anyhow::bail!("phase record for unknown job {job}"),
            }
        }
        REC_MODEL => {
            *clean = false;
            let job = c.u64()?;
            let name = c.str()?;
            let want = c.u64()?;
            let entry = match replay.jobs.get_mut(&job) {
                Some(e) => e,
                None => anyhow::bail!("model record for unknown job {job}"),
            };
            // a bad blob is a warning, not a replay failure: the job just
            // loses its artifact and will be re-run from its checkpoint
            match load_blob(&dir.join(&name), want) {
                Ok(m) => entry.model = Some(m),
                Err(e) => replay
                    .warnings
                    .push(format!("job {job}: model blob {name}: {e}")),
            }
        }
        REC_DRAIN => *clean = true,
        other => anyhow::bail!("unknown record tag {other}"),
    }
    c.done()?;
    Ok(())
}

fn load_blob(path: &Path, want: u64) -> anyhow::Result<FittedModel> {
    let blob = std::fs::read(path).map_err(|e| anyhow::anyhow!("unreadable: {e}"))?;
    let got = fnv1a(&blob);
    anyhow::ensure!(
        got == want,
        "ModelBlobCorrupt: checksum {got:#018x} does not match journaled {want:#018x}"
    );
    FittedModel::from_bytes(&blob)
}

// -- little-endian record primitives ----------------------------------

fn w_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w_str(out: &mut Vec<u8>, s: &str) {
    w_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked reader over one record payload.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.buf.len() - self.pos >= n, "truncated record");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow::anyhow!("non-UTF-8 string"))?
            .to_string())
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.pos == self.buf.len(), "trailing record bytes");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("psfit-journal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn submit_phase_model_drain_replay_roundtrip() {
        let dir = tmpdir("roundtrip");
        let spec = JobSpec {
            seed: 7,
            config: r#"{"solver": {"max_iters": 9}}"#.into(),
            ..Default::default()
        };
        let model = FittedModel::from_solution(4, 1, vec![1], &[0.0, 2.5, 0.0, 0.0], -0.5);
        {
            let (mut j, replay) = Journal::open(&dir).unwrap();
            assert!(replay.jobs.is_empty());
            assert!(replay.clean_shutdown, "empty journal counts as clean");
            j.record_submit(1, "first", &spec).unwrap();
            j.record_phase(1, JobPhase::Running, false, 0, f64::NAN, 0.0, "")
                .unwrap();
            j.record_model(1, &model).unwrap();
            j.record_phase(1, JobPhase::Done, true, 9, -0.5, 0.25, "")
                .unwrap();
        }
        {
            let (mut j, replay) = Journal::open(&dir).unwrap();
            assert_eq!(replay.records, 4);
            assert!(!replay.clean_shutdown, "no drain marker => crash");
            assert!(!replay.truncated);
            assert!(replay.warnings.is_empty(), "{:?}", replay.warnings);
            let e = &replay.jobs[&1];
            assert_eq!(e.name, "first");
            assert_eq!(e.spec, spec);
            assert_eq!(e.phase, JobPhase::Done);
            assert!(e.converged);
            assert_eq!(e.iters, 9);
            assert_eq!(e.objective.to_bits(), (-0.5f64).to_bits());
            assert_eq!(e.model.as_ref().unwrap(), &model);
            j.record_drain().unwrap();
        }
        let (_, replay) = Journal::open(&dir).unwrap();
        assert!(replay.clean_shutdown, "drain marker => clean");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_dropped_and_appending_resumes() {
        let dir = tmpdir("torn");
        {
            let (mut j, _) = Journal::open(&dir).unwrap();
            j.record_submit(1, "keep", &JobSpec::default()).unwrap();
            j.record_submit(2, "torn", &JobSpec::default()).unwrap();
        }
        let path = dir.join(JOURNAL_FILE);
        let full = std::fs::read(&path).unwrap();
        // cut into the middle of the second record
        let file = OpenOptions::new().write(true).open(&path).unwrap();
        file.set_len(full.len() as u64 - 10).unwrap();
        drop(file);
        {
            let (mut j, replay) = Journal::open(&dir).unwrap();
            assert!(replay.truncated, "torn tail must be flagged");
            assert_eq!(replay.records, 1);
            assert!(replay.jobs.contains_key(&1) && !replay.jobs.contains_key(&2));
            // the torn bytes were dropped, so a fresh append lands clean
            j.record_submit(3, "after", &JobSpec::default()).unwrap();
        }
        let (_, replay) = Journal::open(&dir).unwrap();
        assert!(!replay.truncated);
        assert_eq!(replay.records, 2);
        assert!(replay.jobs.contains_key(&3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_record_is_a_named_error() {
        let dir = tmpdir("corrupt");
        {
            let (mut j, _) = Journal::open(&dir).unwrap();
            j.record_submit(1, "a", &JobSpec::default()).unwrap();
            j.record_submit(2, "b", &JobSpec::default()).unwrap();
        }
        let path = dir.join(JOURNAL_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        // flip a byte inside the *first* record's payload (not the tail)
        bytes[16] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        let err = Journal::open(&dir).unwrap_err().to_string();
        assert!(err.contains("JournalCorrupt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_model_blob_is_a_warning_not_a_failure() {
        let dir = tmpdir("blobless");
        let model = FittedModel::from_solution(3, 1, vec![0], &[1.0, 0.0, 0.0], 0.0);
        {
            let (mut j, _) = Journal::open(&dir).unwrap();
            j.record_submit(1, "a", &JobSpec::default()).unwrap();
            j.record_model(1, &model).unwrap();
        }
        std::fs::remove_file(model_blob_path(&dir, 1)).unwrap();
        let (_, replay) = Journal::open(&dir).unwrap();
        assert_eq!(replay.warnings.len(), 1, "{:?}", replay.warnings);
        assert!(replay.jobs[&1].model.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

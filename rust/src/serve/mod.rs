//! `psfit serve`: a multi-tenant fit/predict daemon over a shared worker
//! fleet.
//!
//! The daemon listens for [`crate::network::socket::wire`] client frames
//! (`Submit`, `Status`, `Predict`, `Jobs`) and runs each submitted fit on
//! its own thread as a [`crate::network::socket::SocketCluster`] over the
//! shared fleet of `psfit worker` processes.  Because a worker serves one
//! *node session per connection*, concurrent jobs multiplex over the same
//! fleet without stepping on each other's solver state — two tenants can
//! fit different problems on the same three workers at the same time.
//!
//! Completed jobs keep only their [`FittedModel`] (the κ-sparse support),
//! so the prediction endpoint answers support-only sparse dot products
//! with latency independent of the training dimension and of any fit
//! currently running.
//!
//! With `--state-dir` the control plane is **durable**: every submit,
//! phase transition, and model artifact is journaled ([`journal`], PSJ1
//! records + atomic PSM1 blobs), each job's fit auto-writes a per-job
//! PSF1 checkpoint, and on startup the daemon replays the journal —
//! completed jobs answer `predict` bit-identically, unfinished jobs are
//! re-dialed onto the fleet and resumed from their checkpoint via
//! `solve_checkpointed`.  SIGTERM/SIGINT flips the daemon into *draining*
//! (new submits get a structured `Rejected` reply, running jobs get a
//! grace window, and a clean-shutdown marker lets the next startup
//! distinguish a drain from a crash).

pub mod client;
pub mod journal;
pub mod model;

pub use client::ServeClient;
pub use model::FittedModel;

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::admm::{self, SolveOptions};
use crate::config::{BackendKind, Config, TransportKind};
use crate::data::{SyntheticSpec, Task};
use crate::losses::{make_loss, LossKind};
use crate::network::socket::wire::{self, JobSpec, JobStatus, JobSummary, WireCommand};
use crate::network::socket::{
    connect, spawn_local_worker, Endpoint, SocketCluster, SocketListener, SocketStream,
};
use crate::serve::journal::Journal;
use crate::util::json::Json;

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    /// Accepted, not yet running.
    Queued,
    /// Fitting on the worker fleet.
    Running,
    /// Finished; a fitted model is available.
    Done,
    /// Fit failed; see the status message.
    Failed,
    /// `solver.deadline_ms` cut the fit off at a round boundary; the
    /// best-so-far model and objective are available, like `Done`, but
    /// the result is flagged as partial rather than converged.
    TimedOut,
}

impl JobPhase {
    /// Wire code (the `phase` byte of `JobStatus` / `JobSummary`).
    pub fn code(&self) -> u8 {
        match self {
            JobPhase::Queued => 0,
            JobPhase::Running => 1,
            JobPhase::Done => 2,
            JobPhase::Failed => 3,
            JobPhase::TimedOut => 4,
        }
    }

    /// Decode a wire phase byte.
    pub fn from_code(code: u8) -> anyhow::Result<JobPhase> {
        Ok(match code {
            0 => JobPhase::Queued,
            1 => JobPhase::Running,
            2 => JobPhase::Done,
            3 => JobPhase::Failed,
            4 => JobPhase::TimedOut,
            other => anyhow::bail!("unknown job phase code {other}"),
        })
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Running => "running",
            JobPhase::Done => "done",
            JobPhase::Failed => "failed",
            JobPhase::TimedOut => "timed_out",
        }
    }
}

/// Daemon settings.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Client-facing listen address.
    pub listen: String,
    /// Addresses of already-running `psfit worker` processes.
    pub workers: Vec<String>,
    /// Additionally spawn this many in-process workers on ephemeral
    /// localhost ports (single-machine quickstart; `psfit serve
    /// --local-fleet 3` needs no separate worker processes).
    pub local_fleet: usize,
    /// Per-attempt worker connect timeout (milliseconds).
    pub connect_timeout_ms: u64,
    /// Worker read timeout per reply (milliseconds; 0 waits forever).
    pub read_timeout_ms: u64,
    /// Worker connect retries after the first attempt.
    pub connect_retries: u32,
    /// Durable state directory: job journal, model artifacts, and per-job
    /// PSF1 checkpoints live here; `None` keeps everything in memory.
    pub state_dir: Option<String>,
    /// How long a drain (SIGTERM/SIGINT) waits for running jobs before
    /// exiting anyway (their checkpoints make the wait optional).
    pub drain_grace_ms: u64,
    /// Whether to journal at all when a state dir is set (`serve.journal`
    /// config knob; per-job checkpoints are still written when `false`).
    pub journal: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            listen: "127.0.0.1:7700".to_string(),
            workers: Vec::new(),
            local_fleet: 0,
            connect_timeout_ms: 3000,
            read_timeout_ms: 30_000,
            connect_retries: 3,
            state_dir: None,
            drain_grace_ms: 10_000,
            journal: true,
        }
    }
}

/// One job's record: live status plus, once done, the fitted model.  The
/// spec is kept so a replayed `queued`/`running` job can be re-executed.
struct JobEntry {
    name: String,
    spec: JobSpec,
    phase: JobPhase,
    converged: bool,
    iters: u64,
    objective: f64,
    wall_seconds: f64,
    message: String,
    model: Option<Arc<FittedModel>>,
}

/// Shared daemon state: the job table, the worker fleet, and (with
/// `--state-dir`) the journal plus the drain flag.
struct ServeState {
    jobs: Mutex<BTreeMap<u64, JobEntry>>,
    next_id: AtomicU64,
    fleet: Vec<String>,
    connect_timeout_ms: u64,
    read_timeout_ms: u64,
    connect_retries: u32,
    state_dir: Option<PathBuf>,
    journal: Option<Mutex<Journal>>,
    draining: AtomicBool,
    active_jobs: AtomicU64,
    drain_grace_ms: u64,
}

impl ServeState {
    fn lock(&self) -> MutexGuard<'_, BTreeMap<u64, JobEntry>> {
        // a poisoned table (a panicking job thread) must not take the
        // daemon down with it
        self.jobs.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Append one journal record; a write failure is logged, not fatal —
    /// the daemon keeps serving from memory (durability degrades, the
    /// control plane does not stop).
    fn journal_with(&self, what: &str, f: impl FnOnce(&mut Journal) -> anyhow::Result<()>) {
        if let Some(j) = &self.journal {
            let mut g = j.lock().unwrap_or_else(|p| p.into_inner());
            if let Err(e) = f(&mut g) {
                eprintln!("[serve] journal {what} failed: {e}");
            }
        }
    }
}

/// Run the daemon until the process is killed: assemble the fleet, bind,
/// announce `psfit serve listening on <addr> (<n> worker(s))` on stdout,
/// and serve client sessions forever.
pub fn run_serve(opts: &ServeOpts) -> anyhow::Result<()> {
    let (listener, state) = bind_serve(opts)?;
    println!(
        "psfit serve listening on {} ({} worker(s))",
        listener.local_endpoint(),
        state.fleet.len()
    );
    let _ = std::io::stdout().flush();
    #[cfg(unix)]
    {
        install_drain_handler();
        let st = state.clone();
        std::thread::Builder::new()
            .name("psfit-drain".into())
            .spawn(move || drain_watcher(st))
            .map_err(|e| anyhow::anyhow!("cannot spawn drain watcher: {e}"))?;
    }
    serve_loop(listener, state)
}

/// Process-wide "a drain was requested" latch, set from the signal
/// handler (an atomic store is async-signal-safe; everything else happens
/// on the watcher thread).
static DRAIN_REQUESTED: AtomicBool = AtomicBool::new(false);

/// Install SIGTERM/SIGINT handlers via a locally-declared libc `signal`
/// (the `util::mmap` idiom — no new dependencies).
#[cfg(unix)]
fn install_drain_handler() {
    use std::os::raw::c_int;
    unsafe extern "C" fn on_signal(_sig: c_int) {
        DRAIN_REQUESTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: c_int, handler: usize) -> usize;
    }
    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;
    let handler = on_signal as unsafe extern "C" fn(c_int) as usize;
    unsafe {
        signal(SIGINT, handler);
        signal(SIGTERM, handler);
    }
}

/// Graceful drain: flip the daemon into rejecting submits, give running
/// jobs a grace window (they checkpoint as they go, so the wait is a
/// courtesy, not a requirement), journal the clean-shutdown marker, and
/// exit 0.
#[cfg(unix)]
fn drain_watcher(state: Arc<ServeState>) {
    while !DRAIN_REQUESTED.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(50));
    }
    state.draining.store(true, Ordering::SeqCst);
    println!(
        "draining: rejecting new submits; waiting up to {} ms for {} running job(s)",
        state.drain_grace_ms,
        state.active_jobs.load(Ordering::SeqCst)
    );
    let _ = std::io::stdout().flush();
    let deadline = Instant::now() + Duration::from_millis(state.drain_grace_ms);
    while state.active_jobs.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(25));
    }
    let leftover = state.active_jobs.load(Ordering::SeqCst);
    state.journal_with("drain marker", |j| j.record_drain());
    if leftover == 0 {
        println!("drained: clean shutdown");
    } else {
        println!("drained: clean shutdown ({leftover} job(s) left checkpointed for resume)");
    }
    let _ = std::io::stdout().flush();
    std::process::exit(0);
}

/// Spawn an in-process daemon on an ephemeral localhost port, backed by
/// `local_fleet` in-process workers, and return its address — the test
/// harness's one-call cluster-in-a-process.
pub fn spawn_local_serve(local_fleet: usize) -> anyhow::Result<String> {
    spawn_serve(&ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        local_fleet,
        ..Default::default()
    })
}

/// [`spawn_local_serve`] with full control over the daemon settings —
/// the degradation tests point `opts.workers` at deliberately flaky
/// fleets to watch jobs land in the `failed` phase.
pub fn spawn_serve(opts: &ServeOpts) -> anyhow::Result<String> {
    let (listener, state) = bind_serve(opts)?;
    let addr = listener.local_endpoint();
    std::thread::Builder::new()
        .name("psfit-serve".into())
        .spawn(move || {
            if let Err(e) = serve_loop(listener, state) {
                eprintln!("[serve] listener exited: {e}");
            }
        })
        .map_err(|e| anyhow::anyhow!("cannot spawn serve thread: {e}"))?;
    Ok(addr)
}

fn bind_serve(opts: &ServeOpts) -> anyhow::Result<(SocketListener, Arc<ServeState>)> {
    let mut fleet = opts.workers.clone();
    for _ in 0..opts.local_fleet {
        fleet.push(spawn_local_worker()?);
    }
    anyhow::ensure!(
        !fleet.is_empty(),
        "psfit serve needs at least one worker (--workers or --local-fleet)"
    );
    let state_dir = opts.state_dir.as_ref().map(PathBuf::from);
    let mut jobs = BTreeMap::new();
    let mut next_id = 0u64;
    let mut resume = Vec::new();
    let mut journal = None;
    if let Some(dir) = &state_dir {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("cannot create state dir {}: {e}", dir.display()))?;
        if opts.journal {
            let (j, replay) = Journal::open(dir)?;
            for w in &replay.warnings {
                eprintln!("[serve] journal warning: {w}");
            }
            for (&job, r) in &replay.jobs {
                next_id = next_id.max(job);
                // unfinished jobs — and finished ones whose artifact did
                // not survive — go back to `queued` and re-run; their
                // PSF1 checkpoint makes the re-run a bit-exact resume
                let needs_run = matches!(r.phase, JobPhase::Queued | JobPhase::Running)
                    || (matches!(r.phase, JobPhase::Done | JobPhase::TimedOut)
                        && r.model.is_none());
                jobs.insert(
                    job,
                    JobEntry {
                        name: r.name.clone(),
                        spec: r.spec.clone(),
                        phase: if needs_run { JobPhase::Queued } else { r.phase },
                        converged: r.converged,
                        iters: r.iters,
                        objective: r.objective,
                        wall_seconds: r.wall_seconds,
                        message: r.message.clone(),
                        model: r.model.clone().map(Arc::new),
                    },
                );
                if needs_run {
                    resume.push(job);
                }
            }
            if replay.records > 0 {
                println!(
                    "journal: replayed {} record(s), {} job(s){}",
                    replay.records,
                    replay.jobs.len(),
                    if replay.truncated { " (torn tail dropped)" } else { "" }
                );
                println!(
                    "recovery: {}; {} job(s) to resume",
                    if replay.clean_shutdown {
                        "previous daemon drained cleanly"
                    } else {
                        "crash detected"
                    },
                    resume.len()
                );
                let _ = std::io::stdout().flush();
            }
            journal = Some(Mutex::new(j));
        }
    }
    let listener = SocketListener::bind(&Endpoint::parse(&opts.listen))?;
    let state = Arc::new(ServeState {
        jobs: Mutex::new(jobs),
        next_id: AtomicU64::new(next_id),
        fleet,
        connect_timeout_ms: opts.connect_timeout_ms,
        read_timeout_ms: opts.read_timeout_ms,
        connect_retries: opts.connect_retries,
        state_dir,
        journal,
        draining: AtomicBool::new(false),
        active_jobs: AtomicU64::new(0),
        drain_grace_ms: opts.drain_grace_ms,
    });
    if !resume.is_empty() {
        // re-dial the fleet off the bind path so the daemon answers
        // status/predict for completed jobs immediately
        let st = state.clone();
        std::thread::Builder::new()
            .name("psfit-recovery".into())
            .spawn(move || {
                await_fleet(&st);
                for job in resume {
                    start_job(&st, job);
                }
            })
            .map_err(|e| anyhow::anyhow!("cannot spawn recovery thread: {e}"))?;
    }
    Ok((listener, state))
}

/// Probe every fleet address once with the shared backoff-equipped
/// [`connect`] so resumed jobs start against workers that are actually
/// back.  An unreachable worker is logged, not fatal — each job's own
/// cluster connect retries again.
fn await_fleet(state: &ServeState) {
    let timeout = Duration::from_millis(state.connect_timeout_ms.max(1));
    for addr in &state.fleet {
        let ep = Endpoint::parse(addr);
        match connect(&ep, timeout, state.connect_retries.max(5)) {
            Ok(mut s) => {
                // complete the handshake and part with `Shutdown` so the
                // worker sees a clean probe session, not a protocol error
                let _ = wire::client_handshake(&mut s)
                    .and_then(|_| wire::write_frame(&mut s, &WireCommand::Shutdown));
                eprintln!("[serve] re-dialed worker {addr}");
            }
            Err(e) => {
                eprintln!("[serve] worker {addr} still unreachable ({e}); jobs will retry")
            }
        }
    }
}

fn serve_loop(listener: SocketListener, state: Arc<ServeState>) -> anyhow::Result<()> {
    loop {
        let stream = listener
            .accept()
            .map_err(|e| anyhow::anyhow!("accept failed: {e}"))?;
        let st = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = client_session(stream, st) {
                eprintln!("[serve] client session ended: {e}");
            }
        });
    }
}

/// One client connection.  Bad requests (unknown job, model not ready)
/// get an `Error` reply but keep the session open; only wire-level
/// failures and `Shutdown` end it.
fn client_session(mut stream: SocketStream, state: Arc<ServeState>) -> anyhow::Result<()> {
    wire::server_handshake(&mut stream)?;
    loop {
        let Some((cmd, _)) = wire::read_frame(&mut stream)? else {
            return Ok(());
        };
        let reply = match cmd {
            WireCommand::Submit { name, spec } => {
                if state.draining.load(Ordering::SeqCst) {
                    // structured refusal, distinct from `Error`: clients
                    // must not transport-retry a deliberate shutdown
                    WireCommand::Rejected {
                        reason: "draining: daemon is shutting down and not accepting new jobs"
                            .to_string(),
                    }
                } else {
                    let job = submit_job(&state, name, spec);
                    WireCommand::Submitted { job }
                }
            }
            WireCommand::Status { job } => match status_of(&state, job) {
                Some(st) => WireCommand::StatusReply(Box::new(st)),
                None => WireCommand::Error {
                    message: format!("no such job {job}"),
                },
            },
            WireCommand::Predict { job, features } => {
                let model = state.lock().get(&job).and_then(|e| e.model.clone());
                match model {
                    Some(m) => WireCommand::PredictReply {
                        values: m.predict_sparse(&features),
                    },
                    None => WireCommand::Error {
                        message: format!("job {job} has no fitted model yet"),
                    },
                }
            }
            WireCommand::Jobs => {
                let jobs = state
                    .lock()
                    .iter()
                    .map(|(&job, e)| JobSummary {
                        job,
                        phase: e.phase.code(),
                        name: e.name.clone(),
                        message: e.message.clone(),
                    })
                    .collect();
                WireCommand::JobsReply { jobs }
            }
            WireCommand::Shutdown => return Ok(()),
            other => WireCommand::Error {
                message: format!("psfit serve cannot handle `{}`", other.name()),
            },
        };
        wire::write_frame(&mut stream, &reply)?;
    }
}

/// Register a job, journal the submission, and start fitting it on its
/// own thread.
fn submit_job(state: &Arc<ServeState>, name: String, spec: JobSpec) -> u64 {
    let job = state.next_id.fetch_add(1, Ordering::SeqCst) + 1;
    state.journal_with("submit", |j| j.record_submit(job, &name, &spec));
    state.lock().insert(
        job,
        JobEntry {
            name,
            spec,
            phase: JobPhase::Queued,
            converged: false,
            iters: 0,
            objective: f64::NAN,
            wall_seconds: 0.0,
            message: String::new(),
            model: None,
        },
    );
    start_job(state, job);
    job
}

/// Run (or resume) job `job` on its own thread.  Shared by fresh submits
/// and startup recovery — both read the spec out of the job table, so a
/// replayed job re-executes exactly as submitted; its auto-written PSF1
/// checkpoint turns the re-execution into a bit-exact resume.
fn start_job(state: &Arc<ServeState>, job: u64) {
    let st = state.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("psfit-job-{job}"))
        .spawn(move || {
            st.active_jobs.fetch_add(1, Ordering::SeqCst);
            let spec = match st.lock().get_mut(&job) {
                Some(e) => {
                    e.phase = JobPhase::Running;
                    e.spec.clone()
                }
                None => {
                    st.active_jobs.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
            };
            st.journal_with("phase", |j| {
                j.record_phase(job, JobPhase::Running, false, 0, f64::NAN, 0.0, "")
            });
            match execute_job(&st, job, &spec) {
                Ok(done) => {
                    // artifact before phase record: replay must never see
                    // a finished job without a loadable model
                    st.journal_with("model", |j| j.record_model(job, &done.model));
                    // a deadline-clipped fit is a partial success: the
                    // best-so-far model stays queryable, the phase says so
                    let phase = if done.timed_out {
                        JobPhase::TimedOut
                    } else {
                        JobPhase::Done
                    };
                    st.journal_with("phase", |j| {
                        j.record_phase(
                            job,
                            phase,
                            done.converged,
                            done.iters,
                            done.model.objective,
                            done.wall_seconds,
                            "",
                        )
                    });
                    if let Some(e) = st.lock().get_mut(&job) {
                        e.phase = phase;
                        e.converged = done.converged;
                        e.iters = done.iters;
                        e.objective = done.model.objective;
                        e.wall_seconds = done.wall_seconds;
                        e.model = Some(Arc::new(done.model));
                    }
                    // the mid-fit checkpoint has served its purpose
                    if let Some(dir) = &st.state_dir {
                        let _ = std::fs::remove_file(journal::checkpoint_path(dir, job));
                    }
                }
                Err(err) => {
                    let message = err.to_string();
                    st.journal_with("phase", |j| {
                        j.record_phase(job, JobPhase::Failed, false, 0, f64::NAN, 0.0, &message)
                    });
                    if let Some(e) = st.lock().get_mut(&job) {
                        e.phase = JobPhase::Failed;
                        e.message = message;
                    }
                }
            }
            st.active_jobs.fetch_sub(1, Ordering::SeqCst);
        });
    if let Err(e) = spawned {
        eprintln!("[serve] cannot spawn job thread for job {job}: {e}");
    }
}

fn status_of(state: &ServeState, job: u64) -> Option<JobStatus> {
    state.lock().get(&job).map(|e| JobStatus {
        job,
        phase: e.phase.code(),
        converged: e.converged,
        iters: e.iters,
        support_len: e.model.as_ref().map_or(0, |m| m.support.len() as u64),
        objective: e.objective,
        wall_seconds: e.wall_seconds,
        message: e.message.clone(),
    })
}

/// A finished fit, before it is folded into the job table.
struct FinishedJob {
    model: FittedModel,
    converged: bool,
    timed_out: bool,
    iters: u64,
    wall_seconds: f64,
}

/// Run one fit over the shared fleet: build the synthetic problem the
/// spec describes, connect a socket cluster to the first `spec.nodes`
/// workers, solve, and reduce the solution to its support.  With a state
/// dir, the fit auto-writes a per-job PSF1 checkpoint so a daemon restart
/// resumes it bit-identically instead of starting over.
fn execute_job(state: &ServeState, job: u64, spec: &JobSpec) -> anyhow::Result<FinishedJob> {
    let mut cfg = if spec.config.is_empty() {
        Config::default()
    } else {
        Config::from_json(&Json::parse(&spec.config)?)?
    };
    if let Some(dir) = &state.state_dir {
        if cfg.solver.checkpoint.is_empty() {
            cfg.solver.checkpoint = journal::checkpoint_path(dir, job).display().to_string();
        }
    }
    let nodes = (spec.nodes as usize).clamp(1, state.fleet.len());
    cfg.platform.nodes = nodes;
    cfg.platform.backend = BackendKind::Native;
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.workers = state.fleet[..nodes].to_vec();
    cfg.platform.connect_timeout_ms = state.connect_timeout_ms;
    cfg.platform.read_timeout_ms = state.read_timeout_ms;
    cfg.platform.connect_retries = state.connect_retries;

    let mut sspec = SyntheticSpec::regression(spec.n as usize, spec.m as usize, nodes);
    sspec.sparsity_level = spec.sparsity;
    sspec.density = spec.density;
    sspec.noise_std = spec.noise_std;
    sspec.seed = spec.seed;
    // the spec's loss (via its config) decides the label recipe
    sspec.task = match cfg.loss {
        LossKind::Squared => Task::Regression,
        LossKind::Logistic | LossKind::Hinge => Task::Binary,
        LossKind::Softmax => Task::Multiclass { k: cfg.classes },
    };
    cfg.solver.kappa = if spec.kappa > 0 {
        spec.kappa as usize
    } else {
        sspec.kappa()
    };
    let ds = sspec.generate();
    let dim = ds.n_features * ds.width;
    let mut cluster = SocketCluster::connect(&ds, &cfg)?;
    // a job whose config names a checkpoint file gets mid-fit snapshots
    // (and resume-on-resubmit); quorum losses surface through the solve
    // error — death count and last worker error included — and land in
    // the job table as a `failed` status
    let res = if cfg.solver.checkpoint.is_empty() {
        admm::solve(&mut cluster, dim, &cfg, Some(&ds), &SolveOptions::default())?
    } else {
        admm::solve_checkpointed(&mut cluster, dim, &cfg, &ds, &SolveOptions::default())?
    };
    let loss = make_loss(cfg.loss, ds.width.max(cfg.classes));
    let objective = admm::solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &res.x);
    let model = FittedModel::from_solution(ds.n_features, ds.width, res.support, &res.x, objective);
    Ok(FinishedJob {
        model,
        converged: res.converged,
        timed_out: res.timed_out,
        iters: res.iters as u64,
        wall_seconds: res.wall_seconds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_phase_codes_roundtrip() {
        for phase in [
            JobPhase::Queued,
            JobPhase::Running,
            JobPhase::Done,
            JobPhase::Failed,
            JobPhase::TimedOut,
        ] {
            assert_eq!(JobPhase::from_code(phase.code()).unwrap(), phase);
            assert!(!phase.name().is_empty());
        }
        assert!(JobPhase::from_code(99).is_err());
    }

    #[test]
    fn draining_daemon_rejects_submits_with_structured_reply() {
        // workers list is a dead address: submission is rejected before
        // any worker connect happens, so nothing ever dials it
        let opts = ServeOpts {
            listen: "127.0.0.1:0".to_string(),
            workers: vec!["127.0.0.1:9".to_string()],
            ..Default::default()
        };
        let (listener, state) = bind_serve(&opts).unwrap();
        let addr = listener.local_endpoint();
        state.draining.store(true, Ordering::SeqCst);
        let st = state.clone();
        std::thread::spawn(move || {
            let _ = serve_loop(listener, st);
        });
        let mut c = ServeClient::connect(&addr).unwrap();
        let err = c.submit("nope", JobSpec::default()).unwrap_err().to_string();
        assert!(err.contains("draining"), "{err}");
        // non-submit traffic still flows while draining
        assert!(c.jobs().unwrap().is_empty());
    }

    #[test]
    fn serve_refuses_an_empty_fleet() {
        let opts = ServeOpts {
            listen: "127.0.0.1:0".to_string(),
            ..Default::default()
        };
        let err = bind_serve(&opts).unwrap_err().to_string();
        assert!(err.contains("at least one worker"), "{err}");
    }
}

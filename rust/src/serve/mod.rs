//! `psfit serve`: a multi-tenant fit/predict daemon over a shared worker
//! fleet.
//!
//! The daemon listens for [`crate::network::socket::wire`] client frames
//! (`Submit`, `Status`, `Predict`, `Jobs`) and runs each submitted fit on
//! its own thread as a [`crate::network::socket::SocketCluster`] over the
//! shared fleet of `psfit worker` processes.  Because a worker serves one
//! *node session per connection*, concurrent jobs multiplex over the same
//! fleet without stepping on each other's solver state — two tenants can
//! fit different problems on the same three workers at the same time.
//!
//! Completed jobs keep only their [`FittedModel`] (the κ-sparse support),
//! so the prediction endpoint answers support-only sparse dot products
//! with latency independent of the training dimension and of any fit
//! currently running.

pub mod client;
pub mod model;

pub use client::ServeClient;
pub use model::FittedModel;

use std::collections::BTreeMap;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::admm::{self, SolveOptions};
use crate::config::{BackendKind, Config, TransportKind};
use crate::data::{SyntheticSpec, Task};
use crate::losses::{make_loss, LossKind};
use crate::network::socket::wire::{self, JobSpec, JobStatus, JobSummary, WireCommand};
use crate::network::socket::{
    spawn_local_worker, Endpoint, SocketCluster, SocketListener, SocketStream,
};
use crate::util::json::Json;

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    /// Accepted, not yet running.
    Queued,
    /// Fitting on the worker fleet.
    Running,
    /// Finished; a fitted model is available.
    Done,
    /// Fit failed; see the status message.
    Failed,
    /// `solver.deadline_ms` cut the fit off at a round boundary; the
    /// best-so-far model and objective are available, like `Done`, but
    /// the result is flagged as partial rather than converged.
    TimedOut,
}

impl JobPhase {
    /// Wire code (the `phase` byte of `JobStatus` / `JobSummary`).
    pub fn code(&self) -> u8 {
        match self {
            JobPhase::Queued => 0,
            JobPhase::Running => 1,
            JobPhase::Done => 2,
            JobPhase::Failed => 3,
            JobPhase::TimedOut => 4,
        }
    }

    /// Decode a wire phase byte.
    pub fn from_code(code: u8) -> anyhow::Result<JobPhase> {
        Ok(match code {
            0 => JobPhase::Queued,
            1 => JobPhase::Running,
            2 => JobPhase::Done,
            3 => JobPhase::Failed,
            4 => JobPhase::TimedOut,
            other => anyhow::bail!("unknown job phase code {other}"),
        })
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Running => "running",
            JobPhase::Done => "done",
            JobPhase::Failed => "failed",
            JobPhase::TimedOut => "timed_out",
        }
    }
}

/// Daemon settings.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Client-facing listen address.
    pub listen: String,
    /// Addresses of already-running `psfit worker` processes.
    pub workers: Vec<String>,
    /// Additionally spawn this many in-process workers on ephemeral
    /// localhost ports (single-machine quickstart; `psfit serve
    /// --local-fleet 3` needs no separate worker processes).
    pub local_fleet: usize,
    /// Per-attempt worker connect timeout (milliseconds).
    pub connect_timeout_ms: u64,
    /// Worker read timeout per reply (milliseconds; 0 waits forever).
    pub read_timeout_ms: u64,
    /// Worker connect retries after the first attempt.
    pub connect_retries: u32,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            listen: "127.0.0.1:7700".to_string(),
            workers: Vec::new(),
            local_fleet: 0,
            connect_timeout_ms: 3000,
            read_timeout_ms: 30_000,
            connect_retries: 3,
        }
    }
}

/// One job's record: live status plus, once done, the fitted model.
struct JobEntry {
    name: String,
    phase: JobPhase,
    converged: bool,
    iters: u64,
    objective: f64,
    wall_seconds: f64,
    message: String,
    model: Option<Arc<FittedModel>>,
}

/// Shared daemon state: the job table and the worker fleet.
struct ServeState {
    jobs: Mutex<BTreeMap<u64, JobEntry>>,
    next_id: AtomicU64,
    fleet: Vec<String>,
    connect_timeout_ms: u64,
    read_timeout_ms: u64,
    connect_retries: u32,
}

impl ServeState {
    fn lock(&self) -> MutexGuard<'_, BTreeMap<u64, JobEntry>> {
        // a poisoned table (a panicking job thread) must not take the
        // daemon down with it
        self.jobs.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Run the daemon until the process is killed: assemble the fleet, bind,
/// announce `psfit serve listening on <addr> (<n> worker(s))` on stdout,
/// and serve client sessions forever.
pub fn run_serve(opts: &ServeOpts) -> anyhow::Result<()> {
    let (listener, state) = bind_serve(opts)?;
    println!(
        "psfit serve listening on {} ({} worker(s))",
        listener.local_endpoint(),
        state.fleet.len()
    );
    let _ = std::io::stdout().flush();
    serve_loop(listener, state)
}

/// Spawn an in-process daemon on an ephemeral localhost port, backed by
/// `local_fleet` in-process workers, and return its address — the test
/// harness's one-call cluster-in-a-process.
pub fn spawn_local_serve(local_fleet: usize) -> anyhow::Result<String> {
    spawn_serve(&ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        local_fleet,
        ..Default::default()
    })
}

/// [`spawn_local_serve`] with full control over the daemon settings —
/// the degradation tests point `opts.workers` at deliberately flaky
/// fleets to watch jobs land in the `failed` phase.
pub fn spawn_serve(opts: &ServeOpts) -> anyhow::Result<String> {
    let (listener, state) = bind_serve(opts)?;
    let addr = listener.local_endpoint();
    std::thread::Builder::new()
        .name("psfit-serve".into())
        .spawn(move || {
            if let Err(e) = serve_loop(listener, state) {
                eprintln!("[serve] listener exited: {e}");
            }
        })
        .map_err(|e| anyhow::anyhow!("cannot spawn serve thread: {e}"))?;
    Ok(addr)
}

fn bind_serve(opts: &ServeOpts) -> anyhow::Result<(SocketListener, Arc<ServeState>)> {
    let mut fleet = opts.workers.clone();
    for _ in 0..opts.local_fleet {
        fleet.push(spawn_local_worker()?);
    }
    anyhow::ensure!(
        !fleet.is_empty(),
        "psfit serve needs at least one worker (--workers or --local-fleet)"
    );
    let listener = SocketListener::bind(&Endpoint::parse(&opts.listen))?;
    let state = Arc::new(ServeState {
        jobs: Mutex::new(BTreeMap::new()),
        next_id: AtomicU64::new(0),
        fleet,
        connect_timeout_ms: opts.connect_timeout_ms,
        read_timeout_ms: opts.read_timeout_ms,
        connect_retries: opts.connect_retries,
    });
    Ok((listener, state))
}

fn serve_loop(listener: SocketListener, state: Arc<ServeState>) -> anyhow::Result<()> {
    loop {
        let stream = listener
            .accept()
            .map_err(|e| anyhow::anyhow!("accept failed: {e}"))?;
        let st = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = client_session(stream, st) {
                eprintln!("[serve] client session ended: {e}");
            }
        });
    }
}

/// One client connection.  Bad requests (unknown job, model not ready)
/// get an `Error` reply but keep the session open; only wire-level
/// failures and `Shutdown` end it.
fn client_session(mut stream: SocketStream, state: Arc<ServeState>) -> anyhow::Result<()> {
    wire::server_handshake(&mut stream)?;
    loop {
        let Some((cmd, _)) = wire::read_frame(&mut stream)? else {
            return Ok(());
        };
        let reply = match cmd {
            WireCommand::Submit { name, spec } => {
                let job = submit_job(&state, name, spec);
                WireCommand::Submitted { job }
            }
            WireCommand::Status { job } => match status_of(&state, job) {
                Some(st) => WireCommand::StatusReply(Box::new(st)),
                None => WireCommand::Error {
                    message: format!("no such job {job}"),
                },
            },
            WireCommand::Predict { job, features } => {
                let model = state.lock().get(&job).and_then(|e| e.model.clone());
                match model {
                    Some(m) => WireCommand::PredictReply {
                        values: m.predict_sparse(&features),
                    },
                    None => WireCommand::Error {
                        message: format!("job {job} has no fitted model yet"),
                    },
                }
            }
            WireCommand::Jobs => {
                let jobs = state
                    .lock()
                    .iter()
                    .map(|(&job, e)| JobSummary {
                        job,
                        phase: e.phase.code(),
                        name: e.name.clone(),
                    })
                    .collect();
                WireCommand::JobsReply { jobs }
            }
            WireCommand::Shutdown => return Ok(()),
            other => WireCommand::Error {
                message: format!("psfit serve cannot handle `{}`", other.name()),
            },
        };
        wire::write_frame(&mut stream, &reply)?;
    }
}

/// Register a job and start fitting it on its own thread.
fn submit_job(state: &Arc<ServeState>, name: String, spec: JobSpec) -> u64 {
    let job = state.next_id.fetch_add(1, Ordering::SeqCst) + 1;
    state.lock().insert(
        job,
        JobEntry {
            name,
            phase: JobPhase::Queued,
            converged: false,
            iters: 0,
            objective: f64::NAN,
            wall_seconds: 0.0,
            message: String::new(),
            model: None,
        },
    );
    let st = state.clone();
    std::thread::spawn(move || {
        if let Some(e) = st.lock().get_mut(&job) {
            e.phase = JobPhase::Running;
        }
        match execute_job(&st, &spec) {
            Ok(done) => {
                if let Some(e) = st.lock().get_mut(&job) {
                    // a deadline-clipped fit is a partial success: the
                    // best-so-far model stays queryable, the phase says so
                    e.phase = if done.timed_out {
                        JobPhase::TimedOut
                    } else {
                        JobPhase::Done
                    };
                    e.converged = done.converged;
                    e.iters = done.iters;
                    e.objective = done.model.objective;
                    e.wall_seconds = done.wall_seconds;
                    e.model = Some(Arc::new(done.model));
                }
            }
            Err(err) => {
                if let Some(e) = st.lock().get_mut(&job) {
                    e.phase = JobPhase::Failed;
                    e.message = err.to_string();
                }
            }
        }
    });
    job
}

fn status_of(state: &ServeState, job: u64) -> Option<JobStatus> {
    state.lock().get(&job).map(|e| JobStatus {
        job,
        phase: e.phase.code(),
        converged: e.converged,
        iters: e.iters,
        support_len: e.model.as_ref().map_or(0, |m| m.support.len() as u64),
        objective: e.objective,
        wall_seconds: e.wall_seconds,
        message: e.message.clone(),
    })
}

/// A finished fit, before it is folded into the job table.
struct FinishedJob {
    model: FittedModel,
    converged: bool,
    timed_out: bool,
    iters: u64,
    wall_seconds: f64,
}

/// Run one fit over the shared fleet: build the synthetic problem the
/// spec describes, connect a socket cluster to the first `spec.nodes`
/// workers, solve, and reduce the solution to its support.
fn execute_job(state: &ServeState, spec: &JobSpec) -> anyhow::Result<FinishedJob> {
    let mut cfg = if spec.config.is_empty() {
        Config::default()
    } else {
        Config::from_json(&Json::parse(&spec.config)?)?
    };
    let nodes = (spec.nodes as usize).clamp(1, state.fleet.len());
    cfg.platform.nodes = nodes;
    cfg.platform.backend = BackendKind::Native;
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.workers = state.fleet[..nodes].to_vec();
    cfg.platform.connect_timeout_ms = state.connect_timeout_ms;
    cfg.platform.read_timeout_ms = state.read_timeout_ms;
    cfg.platform.connect_retries = state.connect_retries;

    let mut sspec = SyntheticSpec::regression(spec.n as usize, spec.m as usize, nodes);
    sspec.sparsity_level = spec.sparsity;
    sspec.density = spec.density;
    sspec.noise_std = spec.noise_std;
    sspec.seed = spec.seed;
    // the spec's loss (via its config) decides the label recipe
    sspec.task = match cfg.loss {
        LossKind::Squared => Task::Regression,
        LossKind::Logistic | LossKind::Hinge => Task::Binary,
        LossKind::Softmax => Task::Multiclass { k: cfg.classes },
    };
    cfg.solver.kappa = if spec.kappa > 0 {
        spec.kappa as usize
    } else {
        sspec.kappa()
    };
    let ds = sspec.generate();
    let dim = ds.n_features * ds.width;
    let mut cluster = SocketCluster::connect(&ds, &cfg)?;
    // a job whose config names a checkpoint file gets mid-fit snapshots
    // (and resume-on-resubmit); quorum losses surface through the solve
    // error — death count and last worker error included — and land in
    // the job table as a `failed` status
    let res = if cfg.solver.checkpoint.is_empty() {
        admm::solve(&mut cluster, dim, &cfg, Some(&ds), &SolveOptions::default())?
    } else {
        admm::solve_checkpointed(&mut cluster, dim, &cfg, &ds, &SolveOptions::default())?
    };
    let loss = make_loss(cfg.loss, ds.width.max(cfg.classes));
    let objective = admm::solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &res.x);
    let model = FittedModel::from_solution(ds.n_features, ds.width, res.support, &res.x, objective);
    Ok(FinishedJob {
        model,
        converged: res.converged,
        timed_out: res.timed_out,
        iters: res.iters as u64,
        wall_seconds: res.wall_seconds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_phase_codes_roundtrip() {
        for phase in [
            JobPhase::Queued,
            JobPhase::Running,
            JobPhase::Done,
            JobPhase::Failed,
            JobPhase::TimedOut,
        ] {
            assert_eq!(JobPhase::from_code(phase.code()).unwrap(), phase);
            assert!(!phase.name().is_empty());
        }
        assert!(JobPhase::from_code(99).is_err());
    }

    #[test]
    fn serve_refuses_an_empty_fleet() {
        let opts = ServeOpts {
            listen: "127.0.0.1:0".to_string(),
            ..Default::default()
        };
        let err = bind_serve(&opts).unwrap_err().to_string();
        assert!(err.contains("at least one worker"), "{err}");
    }
}

//! Fitted ℓ0 models and support-only prediction.
//!
//! A finished fit is κ-sparse by construction, so the daemon keeps only
//! the support — per class, the `(feature, coefficient)` pairs — and
//! scores sparse feature vectors with a two-pointer merge over sorted
//! index lists.  Prediction cost is O(support + query nnz) per class,
//! independent of the full feature dimension.

/// A fitted model: the κ-sparse solution of one completed job, reduced
/// to its support.
#[derive(Debug, Clone, PartialEq)]
pub struct FittedModel {
    /// Feature dimension n the model was trained on.
    pub n_features: usize,
    /// Prediction width (1, or k for softmax).
    pub width: usize,
    /// Support in the flattened class-major coefficient space (entry `j`
    /// is class `j / n_features`, feature `j % n_features`), sorted.
    pub support: Vec<usize>,
    /// Final objective value (loss + Tikhonov term) at this solution.
    pub objective: f64,
    /// Per-class `(feature, coefficient)` pairs, sorted by feature.
    per_class: Vec<Vec<(u32, f64)>>,
}

impl FittedModel {
    /// Reduce a solver solution to its support.  `support` must be sorted
    /// ascending (as `SolveResult::support` is) and `x` is the flattened
    /// class-major coefficient vector of length `n_features * width`.
    pub fn from_solution(
        n_features: usize,
        width: usize,
        support: Vec<usize>,
        x: &[f64],
        objective: f64,
    ) -> FittedModel {
        let mut per_class = vec![Vec::new(); width];
        for &j in &support {
            let class = j / n_features;
            let feature = (j % n_features) as u32;
            if class < width {
                per_class[class].push((feature, x[j]));
            }
        }
        FittedModel {
            n_features,
            width,
            support,
            objective,
            per_class,
        }
    }

    /// Score one sparse feature vector: `width` raw scores (the linear
    /// predictor per class; for width 1 this is the regression value or
    /// the classification margin).  `features` is `(index, value)` pairs
    /// in any order; duplicate indices contribute additively, indices
    /// outside the trained dimension are ignored.
    pub fn predict_sparse(&self, features: &[(u32, f64)]) -> Vec<f64> {
        let mut q: Vec<(u32, f64)> = features.to_vec();
        q.sort_by_key(|&(i, _)| i);
        self.per_class
            .iter()
            .map(|coef| merge_dot(coef, &q))
            .collect()
    }
}

/// Sparse dot product of two index-sorted `(index, value)` lists.  `b`
/// may contain duplicate indices (each matched occurrence contributes).
fn merge_dot(a: &[(u32, f64)], b: &[(u32, f64)]) -> f64 {
    let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f64);
    while i < a.len() && j < b.len() {
        match a[i].0.cmp(&b[j].0) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                acc += a[i].1 * b[j].1;
                // advance only the query side so duplicate query indices
                // each pair with the same coefficient
                j += 1;
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn support_only_prediction_matches_dense_dot() {
        // width 2, n = 5: coefficients planted on features {1, 4} / {0, 2}
        let n = 5;
        let mut x = vec![0.0; 2 * n];
        x[1] = 2.0; // class 0, feature 1
        x[4] = -1.0; // class 0, feature 4
        x[n] = 0.5; // class 1, feature 0
        x[n + 2] = 3.0; // class 1, feature 2
        let support = vec![1, 4, n, n + 2];
        let m = FittedModel::from_solution(n, 2, support, &x, -1.25);
        let dense = [1.0, 10.0, -2.0, 7.0, 0.5];
        let sparse: Vec<(u32, f64)> = dense
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        let got = m.predict_sparse(&sparse);
        let want0 = 2.0 * dense[1] + (-1.0) * dense[4];
        let want1 = 0.5 * dense[0] + 3.0 * dense[2];
        assert_eq!(got, vec![want0, want1]);
        assert_eq!(m.objective, -1.25);
    }

    #[test]
    fn prediction_handles_unsorted_dupes_and_out_of_range() {
        let n = 4;
        let mut x = vec![0.0; n];
        x[2] = 1.5;
        let m = FittedModel::from_solution(n, 1, vec![2], &x, 0.0);
        // unsorted, duplicated index 2, and an index beyond n
        let got = m.predict_sparse(&[(9, 100.0), (2, 2.0), (0, 5.0), (2, 1.0)]);
        assert_eq!(got, vec![1.5 * 2.0 + 1.5 * 1.0]);
        // empty query scores zero
        assert_eq!(m.predict_sparse(&[]), vec![0.0]);
    }
}

//! Fitted ℓ0 models and support-only prediction.
//!
//! A finished fit is κ-sparse by construction, so the daemon keeps only
//! the support — per class, the `(feature, coefficient)` pairs — and
//! scores sparse feature vectors with a two-pointer merge over sorted
//! index lists.  Prediction cost is O(support + query nnz) per class,
//! independent of the full feature dimension.
//!
//! Models also serialize to the **PSM1** blob format
//! ([`FittedModel::to_bytes`] / [`FittedModel::from_bytes`]) — the
//! artifact the serve journal persists under `--state-dir` so a restarted
//! daemon answers `predict` for completed jobs bit-identically.  The blob
//! is in the PSC1/PSF1 family: magic + version header, little-endian
//! fields, coefficients as `f64::to_bits`, and a trailing FNV-1a checksum
//! so corruption surfaces as a named error instead of silent bad scores.

/// PSM1 model-blob magic.
pub const MODEL_MAGIC: &[u8; 4] = b"PSM1";
/// PSM1 model-blob format version.
pub const MODEL_VERSION: u32 = 1;

/// A fitted model: the κ-sparse solution of one completed job, reduced
/// to its support.
#[derive(Debug, Clone, PartialEq)]
pub struct FittedModel {
    /// Feature dimension n the model was trained on.
    pub n_features: usize,
    /// Prediction width (1, or k for softmax).
    pub width: usize,
    /// Support in the flattened class-major coefficient space (entry `j`
    /// is class `j / n_features`, feature `j % n_features`), sorted.
    pub support: Vec<usize>,
    /// Final objective value (loss + Tikhonov term) at this solution.
    pub objective: f64,
    /// Per-class `(feature, coefficient)` pairs, sorted by feature.
    per_class: Vec<Vec<(u32, f64)>>,
}

impl FittedModel {
    /// Reduce a solver solution to its support.  `support` must be sorted
    /// ascending (as `SolveResult::support` is) and `x` is the flattened
    /// class-major coefficient vector of length `n_features * width`.
    pub fn from_solution(
        n_features: usize,
        width: usize,
        support: Vec<usize>,
        x: &[f64],
        objective: f64,
    ) -> FittedModel {
        let mut per_class = vec![Vec::new(); width];
        for &j in &support {
            let class = j / n_features;
            let feature = (j % n_features) as u32;
            if class < width {
                per_class[class].push((feature, x[j]));
            }
        }
        FittedModel {
            n_features,
            width,
            support,
            objective,
            per_class,
        }
    }

    /// Serialize to a PSM1 blob: header, support, per-class coefficient
    /// lists, and a trailing FNV-1a checksum over everything before it.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MODEL_MAGIC);
        out.extend_from_slice(&MODEL_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.n_features as u64).to_le_bytes());
        out.extend_from_slice(&(self.width as u64).to_le_bytes());
        out.extend_from_slice(&self.objective.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.support.len() as u64).to_le_bytes());
        for &j in &self.support {
            out.extend_from_slice(&(j as u64).to_le_bytes());
        }
        for coef in &self.per_class {
            out.extend_from_slice(&(coef.len() as u64).to_le_bytes());
            for &(feature, value) in coef {
                out.extend_from_slice(&feature.to_le_bytes());
                out.extend_from_slice(&value.to_bits().to_le_bytes());
            }
        }
        let sum = crate::util::fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse a PSM1 blob.  Truncation, a bad magic/version, an absurd
    /// count, or a checksum mismatch is a named `ModelBlobCorrupt` error —
    /// never a panic or a silently wrong model.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<FittedModel> {
        anyhow::ensure!(
            bytes.len() >= 8 && &bytes[..4] == MODEL_MAGIC,
            "ModelBlobCorrupt: not a PSM1 model blob"
        );
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        anyhow::ensure!(
            version == MODEL_VERSION,
            "ModelBlobCorrupt: unsupported PSM1 version {version}"
        );
        anyhow::ensure!(bytes.len() >= 16, "ModelBlobCorrupt: truncated blob");
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().unwrap());
        let actual = crate::util::fnv1a(body);
        anyhow::ensure!(
            stored == actual,
            "ModelBlobCorrupt: checksum mismatch (stored {stored:#018x}, computed {actual:#018x})"
        );
        let mut pos = 8usize;
        let n_features = take_u64(body, &mut pos)? as usize;
        let width = take_u64(body, &mut pos)? as usize;
        let objective = f64::from_bits(take_u64(body, &mut pos)?);
        let support_len = take_u64(body, &mut pos)? as usize;
        anyhow::ensure!(
            support_len <= body.len() / 8,
            "ModelBlobCorrupt: support count {support_len} exceeds the blob size"
        );
        let mut support = Vec::with_capacity(support_len);
        for _ in 0..support_len {
            support.push(take_u64(body, &mut pos)? as usize);
        }
        anyhow::ensure!(
            width <= body.len() / 8,
            "ModelBlobCorrupt: class count {width} exceeds the blob size"
        );
        let mut per_class = Vec::with_capacity(width);
        for _ in 0..width {
            let len = take_u64(body, &mut pos)? as usize;
            anyhow::ensure!(
                len <= body.len() / 12,
                "ModelBlobCorrupt: coefficient count {len} exceeds the blob size"
            );
            let mut coef = Vec::with_capacity(len);
            for _ in 0..len {
                anyhow::ensure!(pos + 12 <= body.len(), "ModelBlobCorrupt: truncated blob");
                let feature = u32::from_le_bytes(body[pos..pos + 4].try_into().unwrap());
                let value =
                    f64::from_bits(u64::from_le_bytes(body[pos + 4..pos + 12].try_into().unwrap()));
                pos += 12;
                coef.push((feature, value));
            }
            per_class.push(coef);
        }
        anyhow::ensure!(pos == body.len(), "ModelBlobCorrupt: trailing garbage");
        Ok(FittedModel {
            n_features,
            width,
            support,
            objective,
            per_class,
        })
    }

    /// Score one sparse feature vector: `width` raw scores (the linear
    /// predictor per class; for width 1 this is the regression value or
    /// the classification margin).  `features` is `(index, value)` pairs
    /// in any order; duplicate indices contribute additively, indices
    /// outside the trained dimension are ignored.
    pub fn predict_sparse(&self, features: &[(u32, f64)]) -> Vec<f64> {
        let mut q: Vec<(u32, f64)> = features.to_vec();
        q.sort_by_key(|&(i, _)| i);
        self.per_class
            .iter()
            .map(|coef| merge_dot(coef, &q))
            .collect()
    }
}

/// Bounds-checked little-endian `u64` read used by the PSM1 decoder.
fn take_u64(buf: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    anyhow::ensure!(*pos + 8 <= buf.len(), "ModelBlobCorrupt: truncated blob");
    let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

/// Sparse dot product of two index-sorted `(index, value)` lists.  `b`
/// may contain duplicate indices (each matched occurrence contributes).
fn merge_dot(a: &[(u32, f64)], b: &[(u32, f64)]) -> f64 {
    let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f64);
    while i < a.len() && j < b.len() {
        match a[i].0.cmp(&b[j].0) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                acc += a[i].1 * b[j].1;
                // advance only the query side so duplicate query indices
                // each pair with the same coefficient
                j += 1;
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn support_only_prediction_matches_dense_dot() {
        // width 2, n = 5: coefficients planted on features {1, 4} / {0, 2}
        let n = 5;
        let mut x = vec![0.0; 2 * n];
        x[1] = 2.0; // class 0, feature 1
        x[4] = -1.0; // class 0, feature 4
        x[n] = 0.5; // class 1, feature 0
        x[n + 2] = 3.0; // class 1, feature 2
        let support = vec![1, 4, n, n + 2];
        let m = FittedModel::from_solution(n, 2, support, &x, -1.25);
        let dense = [1.0, 10.0, -2.0, 7.0, 0.5];
        let sparse: Vec<(u32, f64)> = dense
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        let got = m.predict_sparse(&sparse);
        let want0 = 2.0 * dense[1] + (-1.0) * dense[4];
        let want1 = 0.5 * dense[0] + 3.0 * dense[2];
        assert_eq!(got, vec![want0, want1]);
        assert_eq!(m.objective, -1.25);
    }

    #[test]
    fn prediction_handles_unsorted_dupes_and_out_of_range() {
        let n = 4;
        let mut x = vec![0.0; n];
        x[2] = 1.5;
        let m = FittedModel::from_solution(n, 1, vec![2], &x, 0.0);
        // unsorted, duplicated index 2, and an index beyond n
        let got = m.predict_sparse(&[(9, 100.0), (2, 2.0), (0, 5.0), (2, 1.0)]);
        assert_eq!(got, vec![1.5 * 2.0 + 1.5 * 1.0]);
        // empty query scores zero
        assert_eq!(m.predict_sparse(&[]), vec![0.0]);
    }

    #[test]
    fn psm1_blob_roundtrips_bit_exactly() {
        let n = 6;
        let mut x = vec![0.0; 2 * n];
        x[1] = 0.1 + 0.2; // deliberately non-representable sum
        x[5] = -1e-300;
        x[n + 3] = f64::MIN_POSITIVE;
        let m = FittedModel::from_solution(n, 2, vec![1, 5, n + 3], &x, 0.1 + 0.7);
        let blob = m.to_bytes();
        let back = FittedModel::from_bytes(&blob).unwrap();
        assert_eq!(back, m);
        // predictions off the restored model are bit-identical
        let q = [(1u32, 3.5f64), (3, -2.0), (5, 0.25)];
        let (a, b) = (m.predict_sparse(&q), back.predict_sparse(&q));
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn psm1_blob_rejects_corruption_and_truncation_by_name() {
        let m = FittedModel::from_solution(4, 1, vec![2], &[0.0, 0.0, 1.5, 0.0], 0.0);
        let blob = m.to_bytes();
        // flip one payload byte -> checksum mismatch
        let mut bad = blob.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        let err = FittedModel::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("ModelBlobCorrupt"), "{err}");
        // truncation anywhere is also a named error
        for cut in [0, 3, 8, blob.len() - 1] {
            let err = FittedModel::from_bytes(&blob[..cut]).unwrap_err().to_string();
            assert!(err.contains("ModelBlobCorrupt"), "cut {cut}: {err}");
        }
        // wrong magic
        let mut wrong = blob.clone();
        wrong[0] = b'X';
        let err = FittedModel::from_bytes(&wrong).unwrap_err().to_string();
        assert!(err.contains("not a PSM1"), "{err}");
    }
}

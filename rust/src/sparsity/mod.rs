//! Sparsity machinery for the bi-linear reformulation (Theorem 2.1).
//!
//! `||x||_0 <= kappa`  <=>  exists (s, t):
//!     x^T s = t,   ||x||_1 <= t,   ||s||_1 <= kappa,   ||s||_inf <= 1.
//!
//! This module provides the three geometric operations the coordinator
//! needs, each exact (the projections run in expected O(n) via partial
//! selection, with sort-based `_sorted` reference twins):
//!
//!   * [`project_l1_ball`]      — projection onto {w : ||w||_1 <= r}
//!   * [`project_l1_epigraph`]  — projection onto {(z,t) : ||z||_1 <= t}
//!     (the constraint set of the (z,t)-update (7b))
//!   * [`s_update`]             — the closed-form minimizer of (12) over
//!     S^kappa = {s : ||s||_inf <= 1, ||s||_1 <= kappa}
//!
//! plus hard-thresholding / support utilities shared by the IHT baseline
//! and the solution-polish step.

pub mod projections;
/// Hard-thresholding and support utilities.
pub mod support;

pub use projections::{
    project_l1_ball, project_l1_ball_sorted, project_l1_epigraph, project_l1_epigraph_sorted,
};
pub use support::{hard_threshold, support_f1, support_of, top_k_indices};

/// Closed-form s-update (Eq. 12): minimize (z^T s - tau)^2 over S^kappa.
///
/// Let `s*` be the greedy extreme point (sign pattern on the kappa largest
/// |z| coordinates) and `mx = max_{s in S^kappa} z^T s = sum of kappa
/// largest |z|`.  Then:
///   * |tau| >= mx  ->  s = sign(tau) * s*      (best achievable, residual
///     |tau| - mx)
///   * |tau| <  mx  ->  s = (tau / mx) * s*     (exact zero of the
///     objective; feasible because S^kappa is balanced and convex)
pub fn s_update(z: &[f64], tau: f64, kappa: usize) -> Vec<f64> {
    let n = z.len();
    let kappa = kappa.min(n);
    let mut s = vec![0.0; n];
    if kappa == 0 {
        return s;
    }
    let idx = top_k_indices(z, kappa);
    let mx: f64 = idx.iter().map(|&i| z[i].abs()).sum();
    if mx == 0.0 {
        return s; // z == 0 on its top support: any feasible s gives z^T s = 0
    }
    let scale = if tau.abs() >= mx { tau.signum() } else { tau / mx };
    for &i in &idx {
        s[i] = scale * z[i].signum();
    }
    s
}

/// Value of the bilinear constraint g(z, s, t) = z^T s - t.
pub fn bilinear_g(z: &[f64], s: &[f64], t: f64) -> f64 {
    crate::linalg::ops::dot(z, s) - t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;

    #[test]
    fn s_update_hits_target_exactly_when_reachable() {
        let z = vec![3.0, -1.0, 0.5, 2.0];
        let kappa = 2;
        // mx = 3 + 2 = 5; target 4 < 5 -> exact
        let s = s_update(&z, 4.0, kappa);
        assert!((ops::dot(&z, &s) - 4.0).abs() < 1e-12);
        assert!(s.iter().map(|v| v.abs()).sum::<f64>() <= kappa as f64 + 1e-12);
        assert!(s.iter().all(|v| v.abs() <= 1.0 + 1e-12));
    }

    #[test]
    fn s_update_saturates_when_target_unreachable() {
        let z = vec![3.0, -1.0, 0.5, 2.0];
        let s = s_update(&z, 10.0, 2);
        // best achievable is mx = 5 with sign pattern on {0, 3}
        assert!((ops::dot(&z, &s) - 5.0).abs() < 1e-12);
        assert_eq!(s[1], 0.0);
        assert_eq!(s[2], 0.0);
    }

    #[test]
    fn s_update_negative_target() {
        let z = vec![1.0, -2.0];
        let s = s_update(&z, -3.0, 2);
        assert!((ops::dot(&z, &s) + 3.0).abs() < 1e-12);
    }

    #[test]
    fn s_update_zero_vector() {
        let s = s_update(&[0.0, 0.0, 0.0], 1.0, 2);
        assert_eq!(s, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn s_update_kappa_zero() {
        let s = s_update(&[1.0, 2.0], 1.0, 0);
        assert_eq!(s, vec![0.0, 0.0]);
    }

    #[test]
    fn bilinear_residual_zero_iff_sparse_certificate() {
        // If z is kappa-sparse, s = sign pattern and t = ||z||_1 certify it.
        let z = vec![0.0, 2.0, 0.0, -1.0];
        let t = 3.0;
        let s = s_update(&z, t, 2);
        assert!(bilinear_g(&z, &s, t).abs() < 1e-12);
    }
}

//! Exact Euclidean projections onto the l1 ball and the l1-norm epigraph.
//!
//! The public entry points find their soft-threshold multiplier by
//! `select_nth_unstable_by`-based partial selection over a geometrically
//! shrinking candidate window — expected O(n) total, no full sort.  The
//! historical sort-based O(n log n) versions are kept as `_sorted`
//! reference oracles; the proptest suite pins fast == sorted on random
//! inputs (ties included) on top of the first-order optimality properties
//! (feasibility, idempotence, distance-dominance).
//!
//! Both searches exploit the same prefix property: with magnitudes
//! `a_(1) >= a_(2) >= ...` and prefix sums `S_k`, the predicate
//! `a_(k) > (S_k - r) / k` (ball; `(S_k - s) / (k + 1)` for the
//! epigraph) is monotone in `k` — `h(k) = k a_(k) - S_k + r` decreases
//! because `h(k+1) - h(k) = k (a_(k+1) - a_(k)) <= 0` — so the active
//! count is found by bisection, and each probe only needs a partial
//! selection inside the still-undecided window.

/// Find the active count `k* = max {k : a_(k) > (S_k - r*) / (k + d)}`
/// and return `(S_{k*}, k*)`.  `d` is the denominator shift (0 for the
/// ball, 1 for the epigraph).  `mags` is permuted in place; on return
/// `mags[..k*]` are the `k*` largest magnitudes.  Requires the predicate
/// to hold at k = 1 (both callers guarantee it).
fn active_prefix(mags: &mut [f64], r: f64, d: usize) -> (f64, usize) {
    let n = mags.len();
    let desc = |a: &f64, b: &f64| b.partial_cmp(a).unwrap();
    // invariant: predicate true at `lo` (0 = vacuous), false at `hi`
    // (n + 1 = vacuous); mags[..lo] are the lo largest with sum `acc`,
    // and the undecided candidates live in mags[lo..min(hi, n)]
    let (mut lo, mut hi) = (0usize, n + 1);
    let mut acc = 0.0f64;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        let win = hi.min(n);
        // place the (mid - lo) largest of the window at its front
        mags[lo..win].select_nth_unstable_by(mid - lo - 1, desc);
        let s_mid = acc + mags[lo..mid].iter().sum::<f64>();
        let a_mid = mags[mid - 1];
        if a_mid > (s_mid - r) / (mid + d) as f64 {
            lo = mid;
            acc = s_mid;
        } else {
            hi = mid;
        }
    }
    if lo == 0 {
        // fp-degenerate scale (r below a_(1)'s ulp can defeat the k = 1
        // predicate): treat the single largest magnitude as active, which
        // is what the exact arithmetic would conclude
        let mx = mags.iter().cloned().fold(0.0f64, f64::max);
        return (mx, 1);
    }
    (acc, lo)
}

/// Project `v` onto `{w : ||w||_1 <= r}` (Duchi et al. 2008), with the
/// threshold found by expected-O(n) partial selection.
pub fn project_l1_ball(v: &[f64], r: f64) -> Vec<f64> {
    assert!(r >= 0.0, "radius must be non-negative");
    // non-finite input breaks the selection invariants (partial_cmp on
    // NaN panics, inf poisons the prefix sums); the reply guard keeps
    // such values out of the solver, so reaching here is a caller bug
    debug_assert!(
        v.iter().all(|x| x.is_finite()) && r.is_finite(),
        "project_l1_ball: non-finite input"
    );
    let l1: f64 = v.iter().map(|x| x.abs()).sum();
    if l1 <= r {
        return v.to_vec();
    }
    if r == 0.0 {
        return vec![0.0; v.len()];
    }
    let mut mags: Vec<f64> = v.iter().map(|x| x.abs()).collect();
    // k = 1 predicate: a_(1) > a_(1) - r  <=>  r > 0 (guaranteed above)
    let (cumsum, k) = active_prefix(&mut mags, r, 0);
    let theta = (cumsum - r) / k as f64;
    v.iter()
        .map(|&x| x.signum() * (x.abs() - theta).max(0.0))
        .collect()
}

/// Sort-based reference implementation of [`project_l1_ball`] — the
/// proptest oracle (kept verbatim from the historical O(n log n) path).
pub fn project_l1_ball_sorted(v: &[f64], r: f64) -> Vec<f64> {
    assert!(r >= 0.0, "radius must be non-negative");
    let l1: f64 = v.iter().map(|x| x.abs()).sum();
    if l1 <= r {
        return v.to_vec();
    }
    if r == 0.0 {
        return vec![0.0; v.len()];
    }
    // find threshold theta via the sorted magnitudes
    let mut mags: Vec<f64> = v.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cumsum = 0.0;
    let mut theta = 0.0;
    for (k, &m) in mags.iter().enumerate() {
        cumsum += m;
        let cand = (cumsum - r) / (k + 1) as f64;
        if k + 1 == mags.len() || mags[k + 1] <= cand {
            theta = cand;
            break;
        }
    }
    v.iter()
        .map(|&x| x.signum() * (x.abs() - theta).max(0.0))
        .collect()
}

/// Project `(v, s)` onto the epigraph `{(z, t) : ||z||_1 <= t}`.
///
/// KKT: the projection is `z = soft(v, lam)`, `t = s + lam` for the unique
/// `lam >= 0` solving `phi(lam) = ||soft(v, lam)||_1 - s - lam = 0`
/// (phi is strictly decreasing with slope <= -1).  Special cases:
/// feasible input (lam = 0) and total collapse to the origin
/// (s <= -max|v|).  The multiplier is found by the same expected-O(n)
/// partial selection as [`project_l1_ball`], with the epigraph's shifted
/// denominator (`j + 1` active terms plus the `t` slope).
pub fn project_l1_epigraph(v: &[f64], s: f64) -> (Vec<f64>, f64) {
    debug_assert!(
        v.iter().all(|x| x.is_finite()) && s.is_finite(),
        "project_l1_epigraph: non-finite input"
    );
    let l1: f64 = v.iter().map(|x| x.abs()).sum();
    if l1 <= s {
        return (v.to_vec(), s); // already feasible
    }
    let vmax = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
    if s <= -vmax {
        return (vec![0.0; v.len()], 0.0); // projection is the apex
    }
    let mut mags: Vec<f64> = v.iter().map(|x| x.abs()).collect();
    // k = 1 predicate: a_(1) > (a_(1) - s) / 2  <=>  s > -a_(1) = -vmax
    // (guaranteed above)
    let (cumsum, k) = active_prefix(&mut mags, s, 1);
    let lam = (cumsum - s) / (k + 1) as f64;
    if lam <= 0.0 {
        // the input sits on the boundary to within fp (l1 ~= s): the
        // projection is the point itself
        return (v.to_vec(), s.max(l1));
    }
    let z: Vec<f64> = v
        .iter()
        .map(|&x| x.signum() * (x.abs() - lam).max(0.0))
        .collect();
    (z, s + lam)
}

/// Sort-based reference implementation of [`project_l1_epigraph`] — the
/// proptest oracle (kept verbatim from the historical O(n log n) path).
pub fn project_l1_epigraph_sorted(v: &[f64], s: f64) -> (Vec<f64>, f64) {
    let l1: f64 = v.iter().map(|x| x.abs()).sum();
    if l1 <= s {
        return (v.to_vec(), s); // already feasible
    }
    let vmax = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
    if s <= -vmax {
        return (vec![0.0; v.len()], 0.0); // projection is the apex
    }
    // phi is piecewise linear with breakpoints at the sorted magnitudes:
    // on [a_{k+1}, a_k] (descending), ||soft(v,lam)||_1 = C_k - k*lam with
    // C_k = sum of the k largest magnitudes, so the root is
    // lam = (C_k - s) / (k + 1).
    let mut mags: Vec<f64> = v.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cumsum = 0.0;
    let mut lam = 0.0;
    let mut found = false;
    for (k, &m) in mags.iter().enumerate() {
        cumsum += m;
        let cand = (cumsum - s) / (k + 2) as f64; // k+1 terms active => slope -(k+1)-1
        let next = mags.get(k + 1).copied().unwrap_or(0.0);
        if cand >= next && cand <= m {
            lam = cand;
            found = true;
            break;
        }
    }
    if !found {
        // Floating-point tie at a breakpoint: fall back to bisection on the
        // (strictly decreasing, continuous) phi — always succeeds.
        let (mut lo, mut hi) = (0.0f64, vmax.max(-s) + 1.0);
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            let soft: f64 = v.iter().map(|x| (x.abs() - mid).max(0.0)).sum();
            if soft - s - mid > 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lam = 0.5 * (lo + hi);
    }
    if lam <= 0.0 {
        // the input sits on the boundary to within fp (l1 ~= s): the
        // projection is the point itself
        return (v.to_vec(), s.max(l1));
    }
    let z: Vec<f64> = v
        .iter()
        .map(|&x| x.signum() * (x.abs() - lam).max(0.0))
        .collect();
    let t = s + lam;
    (z, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::util::rng::Rng;

    fn l1(v: &[f64]) -> f64 {
        v.iter().map(|x| x.abs()).sum()
    }

    #[test]
    fn ball_feasible_is_identity() {
        let v = vec![0.2, -0.3, 0.1];
        assert_eq!(project_l1_ball(&v, 1.0), v);
    }

    #[test]
    fn ball_projection_lands_on_boundary() {
        let v = vec![3.0, -4.0, 1.0];
        let w = project_l1_ball(&v, 2.0);
        assert!((l1(&w) - 2.0).abs() < 1e-10);
    }

    #[test]
    fn ball_radius_zero() {
        assert_eq!(project_l1_ball(&[1.0, -2.0], 0.0), vec![0.0, 0.0]);
    }

    #[test]
    fn ball_matches_bruteforce_soft_threshold() {
        // direct bisection on theta as an oracle
        let mut rng = Rng::seed_from(3);
        for _ in 0..50 {
            let v: Vec<f64> = (0..20).map(|_| rng.normal() * 3.0).collect();
            let r = rng.uniform() * 5.0;
            let w = project_l1_ball(&v, r);
            if l1(&v) <= r {
                assert_eq!(w, v);
                continue;
            }
            let (mut lo, mut hi) = (0.0, v.iter().fold(0.0f64, |m, x| m.max(x.abs())));
            for _ in 0..200 {
                let mid = 0.5 * (lo + hi);
                let s: f64 = v.iter().map(|x| (x.abs() - mid).max(0.0)).sum();
                if s > r {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            let oracle: Vec<f64> = v
                .iter()
                .map(|&x| x.signum() * (x.abs() - lo).max(0.0))
                .collect();
            for (a, b) in w.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn epigraph_feasible_is_identity() {
        let v = vec![0.5, -0.5];
        let (z, t) = project_l1_epigraph(&v, 2.0);
        assert_eq!(z, v);
        assert_eq!(t, 2.0);
    }

    #[test]
    fn epigraph_projection_is_feasible_and_tight() {
        let v = vec![3.0, -1.0, 2.0];
        let (z, t) = project_l1_epigraph(&v, 1.0);
        assert!(l1(&z) <= t + 1e-10);
        // infeasible input projects onto the boundary
        assert!((l1(&z) - t).abs() < 1e-10);
    }

    #[test]
    fn epigraph_collapses_to_apex() {
        let v = vec![0.5, -0.25];
        let (z, t) = project_l1_epigraph(&v, -10.0);
        assert_eq!(z, vec![0.0, 0.0]);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn epigraph_projection_minimizes_distance() {
        // compare against dense grid search over the multiplier lam
        let mut rng = Rng::seed_from(7);
        for _ in 0..30 {
            let v: Vec<f64> = (0..8).map(|_| rng.normal() * 2.0).collect();
            let s = rng.normal();
            let (z, t) = project_l1_epigraph(&v, s);
            let d_star = ops::dist2(&z, &v) + (t - s) * (t - s);
            // sample feasible candidates: soft-threshold at many lams
            for i in 0..400 {
                let lam = i as f64 * 0.02;
                let zc: Vec<f64> = v
                    .iter()
                    .map(|&x| x.signum() * (x.abs() - lam).max(0.0))
                    .collect();
                let tc = zc.iter().map(|x| x.abs()).sum::<f64>();
                let d = ops::dist2(&zc, &v) + (tc - s) * (tc - s);
                assert!(
                    d_star <= d + 1e-8,
                    "found better feasible point: {d} < {d_star}"
                );
            }
        }
    }

    #[test]
    fn partial_selection_matches_sorted_reference() {
        let mut rng = Rng::seed_from(13);
        for case in 0..200usize {
            let n = 1 + case % 17;
            let mut v: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0).collect();
            if case % 3 == 0 {
                // plant exact magnitude ties with mixed signs
                for i in 1..n {
                    if i % 2 == 0 {
                        v[i] = -v[i - 1];
                    }
                }
            }
            let r = rng.uniform() * 3.0;
            let a = project_l1_ball(&v, r);
            let b = project_l1_ball_sorted(&v, r);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-10, "ball: {x} vs {y}");
            }
            let s = rng.normal();
            let (za, ta) = project_l1_epigraph(&v, s);
            let (zb, tb) = project_l1_epigraph_sorted(&v, s);
            assert!((ta - tb).abs() < 1e-10, "epigraph t: {ta} vs {tb}");
            for (x, y) in za.iter().zip(&zb) {
                assert!((x - y).abs() < 1e-10, "epigraph: {x} vs {y}");
            }
        }
    }

    #[test]
    fn epigraph_is_idempotent() {
        let mut rng = Rng::seed_from(11);
        for _ in 0..50 {
            let v: Vec<f64> = (0..12).map(|_| rng.normal() * 3.0).collect();
            let s = rng.normal() * 2.0;
            let (z, t) = project_l1_epigraph(&v, s);
            let (z2, t2) = project_l1_epigraph(&z, t);
            for (a, b) in z.iter().zip(&z2) {
                assert!((a - b).abs() < 1e-10);
            }
            assert!((t - t2).abs() < 1e-10);
        }
    }
}

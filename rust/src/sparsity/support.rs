//! Support-set utilities: top-k selection, hard thresholding, recovery
//! metrics.  Shared by the coordinator's solution extraction, the IHT
//! baseline, and the experiment harnesses (Table 1 reports which methods
//! recover the planted support).

/// Indices of the `k` largest-|.| entries (ties broken by lower index,
/// making the selection deterministic).
pub fn top_k_indices(v: &[f64], k: usize) -> Vec<usize> {
    let k = k.min(v.len());
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&a, &b| {
        v[b].abs()
            .partial_cmp(&v[a].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Zero all but the `k` largest-|.| entries (in place), returning the
/// retained support (sorted).
pub fn hard_threshold(v: &mut [f64], k: usize) -> Vec<usize> {
    let mut keep = top_k_indices(v, k);
    keep.sort_unstable();
    let mut ptr = 0;
    for i in 0..v.len() {
        if ptr < keep.len() && keep[ptr] == i {
            ptr += 1;
        } else {
            v[i] = 0.0;
        }
    }
    keep
}

/// Support of `v` under an absolute tolerance.
pub fn support_of(v: &[f64], tol: f64) -> Vec<usize> {
    v.iter()
        .enumerate()
        .filter(|(_, &x)| x.abs() > tol)
        .map(|(i, _)| i)
        .collect()
}

/// F1 score between a recovered support and the ground-truth support.
pub fn support_f1(recovered: &[usize], truth: &[usize]) -> f64 {
    if recovered.is_empty() && truth.is_empty() {
        return 1.0;
    }
    let truth_set: std::collections::HashSet<usize> = truth.iter().copied().collect();
    let tp = recovered.iter().filter(|i| truth_set.contains(i)).count() as f64;
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / recovered.len() as f64;
    let recall = tp / truth.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_selects_largest_magnitudes() {
        let v = vec![0.1, -5.0, 3.0, -0.2, 4.0];
        let mut idx = top_k_indices(&v, 3);
        idx.sort_unstable();
        assert_eq!(idx, vec![1, 2, 4]);
    }

    #[test]
    fn top_k_ties_are_deterministic() {
        let v = vec![1.0, -1.0, 1.0];
        assert_eq!(top_k_indices(&v, 2), vec![0, 1]);
    }

    #[test]
    fn hard_threshold_zeroes_rest() {
        let mut v = vec![0.1, -5.0, 3.0, -0.2, 4.0];
        let keep = hard_threshold(&mut v, 2);
        assert_eq!(keep, vec![1, 4]);
        assert_eq!(v, vec![0.0, -5.0, 0.0, 0.0, 4.0]);
    }

    #[test]
    fn hard_threshold_k_geq_len_is_identity() {
        let mut v = vec![1.0, 2.0];
        hard_threshold(&mut v, 5);
        assert_eq!(v, vec![1.0, 2.0]);
    }

    #[test]
    fn f1_perfect_and_disjoint() {
        assert_eq!(support_f1(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(support_f1(&[4, 5], &[1, 2]), 0.0);
        assert_eq!(support_f1(&[], &[]), 1.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // recovered {1,2}, truth {2,3}: tp=1, p=0.5, r=0.5 -> f1=0.5
        assert!((support_f1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn support_of_tolerance() {
        let v = vec![1e-9, 0.5, -1e-7, 2.0];
        assert_eq!(support_of(&v, 1e-6), vec![1, 3]);
    }
}

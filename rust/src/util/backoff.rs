//! Capped exponential backoff with deterministic seeded jitter.
//!
//! One policy object serves every retry loop in the socket runtime: the
//! initial `SocketCluster` connect, the between-round worker-rejoin
//! probes, and any future reconnecting client.  Delays grow as
//! `base * 2^attempt`, are capped, and carry multiplicative jitter drawn
//! from the crate's seeded PRNG ([`crate::util::rng::Rng`]) — so two
//! runs with the same seed schedule *identical* retry instants, which is
//! what lets the chaos harness reproduce a fault scenario bit-for-bit.

use std::time::Duration;

use crate::util::rng::Rng;

/// Retry-delay policy: capped exponential growth plus seeded jitter.
///
/// Jitter is multiplicative over `[1 - jitter, 1 + jitter]`, so a 25%
/// jitter on a 100 ms base yields delays in `[75, 125]` ms for the first
/// attempt.  All state (the attempt counter and the PRNG) lives in the
/// policy, so each retrying entity owns one `Backoff`.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    jitter: f64,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// Default jitter fraction (±25% around the exponential delay).
    pub const DEFAULT_JITTER: f64 = 0.25;

    /// Policy starting at `base`, never exceeding `cap`, seeded for
    /// deterministic jitter.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff {
            base,
            cap,
            jitter: Self::DEFAULT_JITTER,
            attempt: 0,
            rng: Rng::seed_from(seed),
        }
    }

    /// Override the jitter fraction (`0.0` disables jitter entirely).
    pub fn with_jitter(mut self, jitter: f64) -> Backoff {
        self.jitter = jitter.clamp(0.0, 1.0);
        self
    }

    /// Attempts scheduled so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Delay to wait before the *next* attempt, advancing the counter.
    ///
    /// The first call returns roughly `base`, each subsequent call twice
    /// the previous (pre-jitter), saturating at `cap`.
    pub fn next_delay(&mut self) -> Duration {
        // saturate the shift well before Duration arithmetic could
        let exp = self.attempt.min(32);
        self.attempt = self.attempt.saturating_add(1);
        let raw = self
            .base
            .saturating_mul(1u32.checked_shl(exp).unwrap_or(u32::MAX))
            .min(self.cap);
        let scale = 1.0 + self.jitter * (2.0 * self.rng.uniform() - 1.0);
        Duration::from_secs_f64((raw.as_secs_f64() * scale).max(0.0)).min(self.cap)
    }

    /// Reset the attempt counter (e.g. after a successful reconnect), so
    /// the next failure starts the schedule from `base` again.  The PRNG
    /// stream is *not* rewound — determinism is per-seed, not per-reset.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// Sleep through `backoff.next_delay()` — the helper retry loops call
/// between attempts.
pub fn sleep_next(backoff: &mut Backoff) {
    std::thread::sleep(backoff.next_delay());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn delays_grow_exponentially_and_cap() {
        let mut b = Backoff::new(ms(50), ms(400), 7).with_jitter(0.0);
        let delays: Vec<u128> = (0..6).map(|_| b.next_delay().as_millis()).collect();
        assert_eq!(delays, vec![50, 100, 200, 400, 400, 400]);
        assert_eq!(b.attempts(), 6);
    }

    #[test]
    fn jitter_is_bounded_and_seed_deterministic() {
        let mut a = Backoff::new(ms(100), ms(10_000), 42);
        let mut b = Backoff::new(ms(100), ms(10_000), 42);
        let mut c = Backoff::new(ms(100), ms(10_000), 43);
        let mut saw_different_seed_diverge = false;
        for k in 0..8 {
            let da = a.next_delay();
            let db = b.next_delay();
            let dc = c.next_delay();
            assert_eq!(da, db, "same seed must schedule identical delays");
            if da != dc {
                saw_different_seed_diverge = true;
            }
            let nominal = 100.0 * f64::from(1u32 << k.min(6));
            let lo = nominal * (1.0 - Backoff::DEFAULT_JITTER) - 1.0;
            let hi = (nominal * (1.0 + Backoff::DEFAULT_JITTER) + 1.0).min(10_000.0);
            let got = da.as_secs_f64() * 1e3;
            assert!(got >= lo && got <= hi, "attempt {k}: {got} ms not in [{lo}, {hi}]");
        }
        assert!(saw_different_seed_diverge, "different seeds should jitter apart");
    }

    #[test]
    fn reset_restarts_the_schedule() {
        let mut b = Backoff::new(ms(10), ms(1000), 1).with_jitter(0.0);
        let _ = b.next_delay();
        let _ = b.next_delay();
        assert_eq!(b.next_delay(), ms(40));
        b.reset();
        assert_eq!(b.next_delay(), ms(10));
    }

    #[test]
    fn zero_cap_never_panics() {
        let mut b = Backoff::new(ms(0), ms(0), 9);
        for _ in 0..40 {
            assert_eq!(b.next_delay(), ms(0));
        }
    }
}

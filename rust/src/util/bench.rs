//! Micro-benchmark kit (criterion is not available offline).
//!
//! Adaptive-iteration timing with warmup, median/mean/p10/p90 statistics,
//! and a uniform one-line report format shared by `rust/benches/*` and the
//! EXPERIMENTS.md perf tables.

use std::time::{Duration, Instant};

/// Timing statistics of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Label for reports.
    pub name: String,
    /// Timed iterations executed.
    pub iters: usize,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds per iteration.
    pub median_ns: f64,
    /// 10th-percentile nanoseconds.
    pub p10_ns: f64,
    /// 90th-percentile nanoseconds.
    pub p90_ns: f64,
}

impl BenchStats {
    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Uniform one-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p10 {:>12}  p90 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
        )
    }
}

/// Human-scale formatting of a nanosecond figure.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to fill `target` wall time.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchStats {
    // Warmup + calibration: run until we have an estimate of per-call cost.
    let mut per_call = {
        let t0 = Instant::now();
        f();
        t0.elapsed().as_secs_f64().max(1e-9)
    };
    // a couple more warmup rounds for JIT-ish effects (page faults, caches)
    for _ in 0..2 {
        let t0 = Instant::now();
        f();
        per_call = 0.5 * per_call + 0.5 * t0.elapsed().as_secs_f64().max(1e-9);
    }

    let total = target.as_secs_f64();
    let samples = 16usize;
    let calls_per_sample = ((total / samples as f64) / per_call).ceil().max(1.0) as usize;

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..calls_per_sample {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() / calls_per_sample as f64 * 1e9);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let pct = |p: f64| percentile(&times, p);
    BenchStats {
        name: name.to_string(),
        iters: samples * calls_per_sample,
        mean_ns: mean,
        median_ns: pct(0.5),
        p10_ns: pct(0.1),
        p90_ns: pct(0.9),
    }
}

/// Nearest-rank percentile of an ascending-sorted sample: index
/// `round((len - 1) * p)`.  Truncating instead of rounding (the old
/// behavior) biased every percentile low — p90 of 16 samples read sample
/// 13 rather than 14.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Time a single invocation (for expensive end-to-end runs).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut acc = 0u64;
        let stats = bench("spin", Duration::from_millis(50), || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        std::hint::black_box(acc);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.p10_ns <= stats.median_ns);
        assert!(stats.median_ns <= stats.p90_ns + 1.0);
        assert!(stats.iters >= 16);
    }

    #[test]
    fn percentile_rounds_to_nearest_rank() {
        let v: Vec<f64> = (0..16).map(|i| i as f64).collect();
        // regression: (len-1)*p truncated gave 13 / 1 / 7 for these
        assert_eq!(percentile(&v, 0.9), 14.0); // round(13.5)
        assert_eq!(percentile(&v, 0.1), 2.0); // round(1.5)
        assert_eq!(percentile(&v, 0.5), 8.0); // round(7.5)
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 15.0);
        assert_eq!(percentile(&[42.0], 0.9), 42.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}

//! Tiny CLI argument parser (clap is not available offline).
//!
//! Grammar: `psfit <subcommand> [--flag] [--key value] ...`.  Unknown keys
//! are errors so typos fail fast; every option can also be read with a
//! default.  Used by `main.rs` and the examples.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + `--key value` options + flags.
#[derive(Debug, Clone)]
pub struct Args {
    /// First positional token, when present.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse an explicit token stream (tests, embedding).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Args> {
        let mut it = args.into_iter().peekable();
        let mut subcommand = None;
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                subcommand = Some(it.next().unwrap());
            }
        }
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(arg) = it.next() {
            let key = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected `--option`, got `{arg}`"))?
                .to_string();
            // `--key=value` form
            if let Some((k, v)) = key.split_once('=') {
                opts.insert(k.to_string(), v.to_string());
                continue;
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    opts.insert(key, it.next().unwrap());
                }
                _ => flags.push(key),
            }
        }
        Ok(Args {
            subcommand,
            opts,
            flags,
            consumed: std::cell::RefCell::new(Vec::new()),
        })
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse_env() -> anyhow::Result<Args> {
        Args::parse_from(std::env::args().skip(1))
    }

    /// Whether a bare `--name` flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    /// The raw value of `--name value`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Parse `--name value` into `T`, or return `default` when absent.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("invalid value for --{name}: `{raw}`")),
        }
    }

    /// Like [`Args::opt`] but an error when missing.
    pub fn require(&self, name: &str) -> anyhow::Result<&str> {
        self.opt(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    /// Error on any option the command never consumed (typo detection).
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let seen = self.consumed.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = args("fig1 --nodes 4 --full --out results/x.csv");
        assert_eq!(a.subcommand.as_deref(), Some("fig1"));
        assert_eq!(a.get("nodes", 0usize).unwrap(), 4);
        assert!(a.flag("full"));
        assert_eq!(a.opt("out"), Some("results/x.csv"));
    }

    #[test]
    fn equals_form() {
        let a = args("train --rho-c=2.5");
        assert_eq!(a.get("rho-c", 0.0f64).unwrap(), 2.5);
    }

    #[test]
    fn defaults_and_missing() {
        let a = args("train");
        assert_eq!(a.get("iters", 100usize).unwrap(), 100);
        assert!(a.require("data").is_err());
    }

    #[test]
    fn negative_number_values() {
        let a = args("train --shift -3.5");
        assert_eq!(a.get("shift", 0.0f64).unwrap(), -3.5);
    }

    #[test]
    fn unknown_rejected() {
        let a = args("train --typo 1");
        let _ = a.get("iters", 1usize);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn bad_parse_reports_key() {
        let a = args("train --iters abc");
        let err = a.get("iters", 1usize).unwrap_err().to_string();
        assert!(err.contains("iters"), "{err}");
    }
}

//! Minimal JSON parser + writer (serde is not available offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest (`artifacts/manifest.json`), experiment configs, and
//! result emission.  Parsing is recursive-descent over bytes; numbers are
//! f64 (ints round-trip exactly up to 2^53, far beyond any shape we store).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64; ints round-trip exactly up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Where and why parsing failed.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Human-readable reason.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ------------------------------------------------------------ accessors

    /// Object member lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that reports *which* key is missing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array elements, if this is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The object map, if this is one.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------ builders

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------ parsing

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

// ---------------------------------------------------------------- writing

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",null,true],"y":{"z":-3}}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"tile_m": 8192, "artifacts": {"gram_tile": {"file": "gram_tile.hlo.txt",
            "inputs": [{"shape": [8192, 512], "dtype": "float32"}]}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("tile_m").unwrap().as_usize(), Some(8192));
        let art = v.get("artifacts").unwrap().get("gram_tile").unwrap();
        let shape = art.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(8192));
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"\\u00e9t\\u00e9 — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("été — ok"));
    }
}

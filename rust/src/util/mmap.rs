//! Dependency-free read-only memory mapping (memmap2 stand-in).
//!
//! [`Mmap`] maps a whole file read-only and exposes it as a `&[u8]` slice;
//! `Drop` unmaps.  On Unix this is a direct `mmap(2)`/`munmap(2)` pair
//! declared locally (std already links libc, so no new crate is needed —
//! the same vendored-stub discipline as the rest of `util::`).  On other
//! platforms the "map" degrades to reading the file into an owned buffer,
//! keeping the API portable at the cost of residency.
//!
//! The mapping is `MAP_PRIVATE` + `PROT_READ`: the kernel pages data in on
//! demand and may drop clean pages under memory pressure, which is exactly
//! the out-of-core contract the `PSD1` shard reader relies on — a shard
//! larger than RAM is consumable as long as the *working set* of a round
//! fits.

use std::fs::File;

/// A read-only mapping of an entire file (see the module docs).
pub struct Mmap {
    inner: Backing,
}

enum Backing {
    /// Empty file: nothing to map (`mmap` rejects length 0).
    Empty,
    #[cfg(unix)]
    Mapped { ptr: *mut u8, len: usize },
    #[cfg(not(unix))]
    Owned(Vec<u8>),
}

// Safety: the mapping is read-only for its whole lifetime (PROT_READ,
// MAP_PRIVATE), so shared references across threads are sound.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(unix)]
mod sys {
    #![allow(non_camel_case_types)]
    pub type c_int = i32;
    pub type off_t = i64;

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: off_t,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> c_int;
    }

    /// `MAP_FAILED` is `(void *) -1`.
    pub fn map_failed() -> *mut u8 {
        usize::MAX as *mut u8
    }
}

impl Mmap {
    /// Map `file` read-only in its entirety.
    pub fn map(file: &File) -> anyhow::Result<Mmap> {
        let len = file.metadata()?.len();
        if len == 0 {
            return Ok(Mmap {
                inner: Backing::Empty,
            });
        }
        if len > usize::MAX as u64 {
            anyhow::bail!("mmap: file too large for address space ({len} bytes)");
        }
        Self::map_len(file, len as usize)
    }

    #[cfg(unix)]
    fn map_len(file: &File, len: usize) -> anyhow::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            anyhow::bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        Ok(Mmap {
            inner: Backing::Mapped { ptr, len },
        })
    }

    #[cfg(not(unix))]
    fn map_len(file: &File, len: usize) -> anyhow::Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = file.try_clone()?;
        f.read_to_end(&mut buf)?;
        Ok(Mmap {
            inner: Backing::Owned(buf),
        })
    }

    /// The mapped bytes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        match &self.inner {
            Backing::Empty => &[],
            #[cfg(unix)]
            // Safety: ptr/len come from a successful mmap that lives until
            // Drop; the mapping is never written through or remapped.
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            #[cfg(not(unix))]
            Backing::Owned(buf) => buf,
        }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Whether the mapping is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.inner {
            // Safety: exactly the region returned by mmap, unmapped once.
            unsafe {
                sys::munmap(ptr, len);
            }
        }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("psfit_mmap_{}_{name}", std::process::id()))
    }

    #[test]
    fn maps_file_contents() {
        let path = tmp_path("contents");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&payload)
            .unwrap();
        let map = Mmap::map(&File::open(&path).unwrap()).unwrap();
        assert_eq!(map.as_slice(), &payload[..]);
        assert_eq!(map.len(), payload.len());
        drop(map);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_maps_empty() {
        let path = tmp_path("empty");
        std::fs::File::create(&path).unwrap();
        let map = Mmap::map(&File::open(&path).unwrap()).unwrap();
        assert!(map.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn map_base_is_page_aligned() {
        let path = tmp_path("aligned");
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&[1u8; 4096])
            .unwrap();
        let map = Mmap::map(&File::open(&path).unwrap()).unwrap();
        // page alignment implies the 64-byte alignment PSD1 sections need
        assert_eq!(map.as_slice().as_ptr() as usize % 64, 0);
        std::fs::remove_file(&path).unwrap();
    }
}

//! Small self-contained substrates: PRNG, JSON, CLI parsing, bench/test
//! kits, and the block-sweep worker pool.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (rand, serde,
//! clap, criterion, proptest) are replaced by the minimal implementations in
//! this module.  Each is tested in its own unit-test block and, for the
//! property-testing kit, exercised heavily by `rust/tests/proptests.rs`.

/// Capped exponential retry backoff with seeded jitter.
pub mod backoff;
pub mod bench;
/// Tiny CLI argument parser (clap stand-in).
pub mod cli;
/// Minimal JSON parser + writer (serde stand-in).
pub mod json;
/// Scoped worker pool for the block sweep.
pub mod pool;
/// Seeded PRNG (rand stand-in).
pub mod rng;
/// Property-testing kit (proptest stand-in).
pub mod testkit;

/// Wall-clock stopwatch used by the metrics ledger and the bench kit.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    /// Seconds since `start`.
    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Milliseconds since `start`.
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

//! Small self-contained substrates: PRNG, JSON, CLI parsing, bench/test
//! kits, and the block-sweep worker pool.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (rand, serde,
//! clap, criterion, proptest) are replaced by the minimal implementations in
//! this module.  Each is tested in its own unit-test block and, for the
//! property-testing kit, exercised heavily by `rust/tests/proptests.rs`.

/// Capped exponential retry backoff with seeded jitter.
pub mod backoff;
pub mod bench;
/// Tiny CLI argument parser (clap stand-in).
pub mod cli;
/// Minimal JSON parser + writer (serde stand-in).
pub mod json;
/// Dependency-free read-only memory mapping (memmap2 stand-in).
pub mod mmap;
/// Scoped worker pool for the block sweep.
pub mod pool;
/// Seeded PRNG (rand stand-in).
pub mod rng;
/// Property-testing kit (proptest stand-in).
pub mod testkit;

/// FNV-1a 64-bit offset basis — the repo's standard content-hash seed
/// (the checkpoint problem hash, wire frame checksums, PSD1 shard headers
/// and the mini-batch chunk schedule all speak this hash).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100_0000_01b3;

/// Fold `bytes` into an FNV-1a state (start from [`FNV_OFFSET`]).
#[inline]
pub fn fnv1a_fold(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(FNV_PRIME);
    }
    state
}

/// FNV-1a 64-bit hash of a byte string.
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_fold(FNV_OFFSET, bytes)
}

/// Wall-clock stopwatch used by the metrics ledger and the bench kit.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    /// Seconds since `start`.
    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Milliseconds since `start`.
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

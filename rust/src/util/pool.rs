//! Dependency-free worker pool for the per-block inner sweeps — the CPU
//! analogue of the paper's per-GPU block queues.
//!
//! [`WorkerPool::run`] executes a batch of jobs on up to `threads` OS
//! threads.  Workers are scoped to the call (`std::thread::scope`), so
//! jobs may borrow the caller's block state without `'static` bounds; the
//! pool object itself is the persistent part — it carries the thread-count
//! policy for a backend's whole lifetime and is the single place a
//! `--threads` knob lands.
//!
//! Determinism contract (see DESIGN.md §Kernel-layer): the pool only
//! decides *which thread* runs a job, never the work inside it.  Jobs must
//! write disjoint outputs (block `j` owns `x_j`, `pred_j`, and its own
//! scratch), and any reduction over job outputs happens in the caller
//! after `run` returns, in a fixed order.  Under that contract solver
//! results are bit-identical at any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Thread-count policy + scoped job runner for block sweeps.
pub struct WorkerPool {
    threads: usize,
    /// Batches dispatched (introspection / tests).
    runs: AtomicUsize,
}

impl WorkerPool {
    /// `threads == 0` selects the host's available parallelism;
    /// `threads == 1` runs every batch inline (no spawns at all).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        WorkerPool {
            threads,
            runs: AtomicUsize::new(0),
        }
    }

    /// Resolved worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Batches dispatched so far.
    pub fn runs(&self) -> usize {
        self.runs.load(Ordering::Relaxed)
    }

    /// Run all `jobs` to completion.  Jobs are claimed from a shared
    /// counter, so a straggling job never blocks an idle worker; a
    /// panicking job propagates when the scope joins.
    pub fn run<F: FnOnce() + Send>(&self, jobs: Vec<F>) {
        self.runs.fetch_add(1, Ordering::Relaxed);
        if self.threads <= 1 || jobs.len() <= 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let slots: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(slots.len());
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(slot) = slots.get(i) else { break };
                    let job = slot.lock().unwrap().take().expect("job claimed twice");
                    job();
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_job_exactly_once() {
        let pool = WorkerPool::new(4);
        let count = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..37)
            .map(|_| {
                || {
                    count.fetch_add(1, Ordering::Relaxed);
                }
            })
            .collect();
        pool.run(jobs);
        assert_eq!(count.load(Ordering::Relaxed), 37);
        assert_eq!(pool.runs(), 1);
    }

    #[test]
    fn disjoint_writes_match_serial_at_any_width() {
        let run_with = |threads: usize| -> Vec<usize> {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0usize; 48];
            let jobs: Vec<_> = out
                .chunks_mut(6)
                .enumerate()
                .map(|(i, chunk)| {
                    move || {
                        for (k, c) in chunk.iter_mut().enumerate() {
                            *c = i * 100 + k;
                        }
                    }
                })
                .collect();
            pool.run(jobs);
            out
        };
        let serial = run_with(1);
        for threads in [2, 3, 8] {
            assert_eq!(run_with(threads), serial);
        }
    }

    #[test]
    fn zero_threads_means_available_parallelism() {
        assert!(WorkerPool::new(0).threads() >= 1);
        assert_eq!(WorkerPool::new(3).threads(), 3);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<fn()> = Vec::new();
        pool.run(jobs);
        assert_eq!(pool.runs(), 1);
    }
}

//! Deterministic PRNG: SplitMix64-seeded xoshiro256++ with Gaussian sampling.
//!
//! Every stochastic component of the library (data generation, node
//! sharding, test harnesses) takes an explicit seed so that experiments and
//! failures reproduce bit-exactly.  The generator matches the published
//! xoshiro256++ reference implementation (Blackman & Vigna).

/// xoshiro256++ — 256-bit state, period 2^256 - 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_cache: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the 256-bit state via SplitMix64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent stream (for per-node / per-block generators).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's unbiased bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (sin, cos) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_cache = Some(r * sin);
            return r * cos;
        }
    }

    /// Standard normal, narrowed to f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices out of `0..n` (partial Fisher-Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from(7);
        let mut mean = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::seed_from(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn choose_indices_distinct_and_in_range() {
        let mut r = Rng::seed_from(5);
        let idx = r.choose_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::seed_from(9);
        let mut a = root.split(0);
        let mut b = root.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

//! Property-testing kit (proptest is not available offline).
//!
//! `run_prop` drives a property over many seeded random cases; on failure it
//! retries with progressively "smaller" size hints to report the smallest
//! failing scale (a lightweight stand-in for shrinking), then panics with
//! the seed so the case replays deterministically.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Random cases to run.
    pub cases: usize,
    /// Root seed (each case derives its own).
    pub seed: u64,
    /// Maximum size hint passed to the generator (e.g. vector length).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 128,
            seed: 0x5EED,
            max_size: 64,
        }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` random cases.  `prop` returns
/// `Err(msg)` to signal a failure.
pub fn run_prop<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut root = Rng::seed_from(cfg.seed);
    for case in 0..cfg.cases {
        // sizes sweep small -> large so early failures are small failures
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let case_seed = root.next_u64();
        let mut rng = Rng::seed_from(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // "shrink": retry smaller sizes with the same seed, report smallest
            let mut smallest = (size, msg.clone());
            for s in 1..size {
                let mut rng = Rng::seed_from(case_seed);
                if let Err(m) = prop(&mut rng, s) {
                    smallest = (s, m);
                    break;
                }
            }
            panic!(
                "property `{name}` failed (case {case}, seed {case_seed:#x}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert two f32 slices are element-wise close (relative tolerance).
pub fn assert_close_f32(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivial", PropConfig::default(), |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, PropConfig::default().cases);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        run_prop("fails", PropConfig::default(), |rng, size| {
            if size > 3 && rng.uniform() < 2.0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.1], 1e-3).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-9], 1e-6).is_ok());
    }
}

//! Native ("CPU") vs XLA-artifact ("GPU") backend parity.
//!
//! Both backends implement the same math — the native `SolveMode::Cg`
//! mirrors the artifact's fixed-iteration CG — so whole solves must agree
//! to f32-accumulation tolerance.  This is the end-to-end proof that the
//! three-layer stack (Pallas kernels -> JAX tile programs -> HLO artifacts
//! -> PJRT execution) computes what the paper's algorithm specifies.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use psfit::config::{BackendKind, Config};
use psfit::data::{SyntheticSpec, Task};
use psfit::driver;
use psfit::losses::LossKind;
use psfit::sparsity::support_f1;

fn artifacts_ready() -> bool {
    let dir = driver::default_artifacts_dir();
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first ({})", dir.display());
    }
    ok
}

fn base_config(kappa: usize) -> Config {
    let mut cfg = Config::default();
    cfg.solver.kappa = kappa;
    cfg.solver.max_iters = 60;
    // 2 inner sweeps != the artifact's baked 3 -> the fused node_sweep
    // path declines and the GRANULAR xla path is exercised; the fused
    // path is covered by `xla_fused_path_matches_native` below.
    cfg.solver.inner_iters = 2;
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.platform.devices_per_node = 2;
    cfg
}

fn run_both(
    spec: &SyntheticSpec,
    mut cfg: Config,
) -> (psfit::admm::SolveResult, psfit::admm::SolveResult) {
    let ds = spec.generate();
    cfg.platform.nodes = ds.nodes();
    cfg.platform.backend = BackendKind::Native;
    let native = driver::fit_with_options(&ds, &cfg, &Default::default(), false).unwrap();
    cfg.platform.backend = BackendKind::Xla;
    let xla = driver::fit_with_options(&ds, &cfg, &Default::default(), false).unwrap();
    (native, xla)
}

#[test]
fn squared_loss_trajectories_match() {
    if !artifacts_ready() {
        return;
    }
    let mut spec = SyntheticSpec::regression(64, 120, 2);
    spec.sparsity_level = 0.8;
    let (native, xla) = run_both(&spec, base_config(13));

    assert_eq!(native.iters, xla.iters, "iteration counts diverged");
    // residual trajectories agree to f32 tolerance
    for (a, b) in native.trace.records.iter().zip(&xla.trace.records) {
        assert!(
            (a.primal - b.primal).abs() < 1e-2 * (1.0 + a.primal),
            "iter {}: primal {} vs {}",
            a.iter,
            a.primal,
            b.primal
        );
        assert!(
            (a.bilinear - b.bilinear).abs() < 1e-2 * (1.0 + a.bilinear),
            "iter {}: bilinear {} vs {}",
            a.iter,
            a.bilinear,
            b.bilinear
        );
    }
    // consensus iterates agree
    for (a, b) in native.z.iter().zip(&xla.z) {
        assert!((a - b).abs() < 5e-3, "{a} vs {b}");
    }
    // identical recovered supports
    assert_eq!(native.support, xla.support);
}

#[test]
fn logistic_loss_supports_match() {
    if !artifacts_ready() {
        return;
    }
    let mut spec = SyntheticSpec::regression(48, 200, 2);
    spec.task = Task::Binary;
    spec.sparsity_level = 0.875;
    let mut cfg = base_config(6);
    cfg.loss = LossKind::Logistic;
    cfg.solver.max_iters = 40;
    let (native, xla) = run_both(&spec, cfg);
    let f1 = support_f1(&native.support, &xla.support);
    assert!(f1 > 0.95, "support agreement f1 = {f1}");
}

#[test]
fn hinge_loss_supports_match() {
    if !artifacts_ready() {
        return;
    }
    let mut spec = SyntheticSpec::regression(48, 200, 2);
    spec.task = Task::Binary;
    spec.sparsity_level = 0.875;
    let mut cfg = base_config(6);
    cfg.loss = LossKind::Hinge;
    cfg.solver.max_iters = 40;
    let (native, xla) = run_both(&spec, cfg);
    let f1 = support_f1(&native.support, &xla.support);
    assert!(f1 > 0.95, "support agreement f1 = {f1}");
}

#[test]
fn xla_fused_path_matches_native() {
    // inner_iters == manifest.inner_sweeps (3) and a single row tile ->
    // the fused node_sweep artifact runs; it must match native exactly
    // like the granular path does.
    if !artifacts_ready() {
        return;
    }
    let mut spec = SyntheticSpec::regression(64, 120, 2);
    spec.sparsity_level = 0.8;
    let mut cfg = base_config(13);
    cfg.solver.inner_iters = 3;
    let (native, xla) = run_both(&spec, cfg);
    assert_eq!(native.iters, xla.iters);
    for (a, b) in native.z.iter().zip(&xla.z) {
        assert!((a - b).abs() < 5e-3, "{a} vs {b}");
    }
    assert_eq!(native.support, xla.support);
}

#[test]
fn xla_fused_and_granular_agree_with_each_other() {
    // 6 sweeps: fused runs 2 node_sweep calls; granular is forced with a
    // prime sweep count (5) on a second config — instead compare fused(6)
    // against native(6) and granular xla via sweeps=5 against native(5).
    // Direct fused-vs-granular at identical sweeps: use 3 (fused) vs a
    // manifest-mismatched 4 (granular) on the SAME dataset and check both
    // land on the same support.
    if !artifacts_ready() {
        return;
    }
    let mut spec = SyntheticSpec::regression(48, 100, 2);
    spec.sparsity_level = 0.875;
    let ds = spec.generate();
    let mut cfg = base_config(6);
    cfg.platform.nodes = 2;
    cfg.platform.backend = BackendKind::Xla;
    cfg.solver.inner_iters = 3; // fused
    let fused = driver::fit_with_options(&ds, &cfg, &Default::default(), false).unwrap();
    cfg.solver.inner_iters = 4; // granular (4 % 3 != 0)
    let granular = driver::fit_with_options(&ds, &cfg, &Default::default(), false).unwrap();
    assert_eq!(fused.support, granular.support);
}

#[test]
fn xla_ledger_records_transfers() {
    if !artifacts_ready() {
        return;
    }
    let spec = SyntheticSpec::regression(32, 80, 2);
    let mut cfg = base_config(6);
    cfg.solver.max_iters = 5;
    cfg.solver.tol_primal = 0.0; // force all 5 iterations
    let ds = spec.generate();
    cfg.platform.nodes = 2;
    cfg.platform.backend = BackendKind::Xla;
    let res = driver::fit_with_options(&ds, &cfg, &Default::default(), false).unwrap();
    let l = &res.transfers;
    assert!(l.h2d_bytes > 0, "no host->device transfers recorded");
    assert!(l.d2h_bytes > 0, "no device->host transfers recorded");
    assert!(l.copy_seconds > 0.0);
    // network ledger too: 5 rounds * 2 nodes * dim * 8 bytes down
    assert_eq!(l.net_down_bytes, 5 * 2 * 32 * 8);
}

#[test]
fn multiclass_softmax_runs_on_both_backends() {
    if !artifacts_ready() {
        return;
    }
    let mut spec = SyntheticSpec::regression(32, 240, 2);
    spec.task = Task::Multiclass { k: 10 }; // matches artifact classes
    spec.sparsity_level = 0.75;
    let mut cfg = base_config(8 * 10);
    cfg.loss = LossKind::Softmax;
    cfg.classes = 10;
    cfg.solver.max_iters = 25;
    let (native, xla) = run_both(&spec, cfg);
    // trajectories in the same ballpark (softmax Newton is iterative; exact
    // equality is not expected, convergence behaviour is)
    let a = native.trace.last().unwrap();
    let b = xla.trace.last().unwrap();
    assert!(
        (a.primal - b.primal).abs() < 0.1 * (1.0 + a.primal.max(b.primal)),
        "{} vs {}",
        a.primal,
        b.primal
    );
}

//! Coordinator-subsystem integration tests: the sync-parity guardrail,
//! crash recovery on the quorum, and the straggler wall-clock win.

use psfit::admm::{self, SolveOptions};
use psfit::config::{Config, CoordinationKind, CoordinatorConfig};
use psfit::coordinator::{AsyncCluster, FaultSpec};
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::harness::straggler::{run_point, StragglerOpts};
use psfit::network::SequentialCluster;
use psfit::sparsity::support_f1;

fn regression_fixture(nodes: usize) -> (psfit::data::Dataset, Config) {
    let mut spec = SyntheticSpec::regression(40, 480, nodes);
    spec.sparsity_level = 0.9;
    spec.noise_std = 0.02;
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = nodes;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.solver.max_iters = 250;
    (ds, cfg)
}

fn full_barrier(heartbeat_ms: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        coordination: CoordinationKind::Async,
        quorum: 1.0,
        max_staleness: 0,
        heartbeat_ms,
        faults: FaultSpec::default(),
    }
}

/// Acceptance guardrail: AsyncCluster(quorum = 1.0, staleness = 0) must
/// reproduce SequentialCluster bit-for-bit on a multi-node fit.
#[test]
fn async_full_barrier_matches_sequential_bit_for_bit() {
    let (ds, cfg) = regression_fixture(3);
    let dim = ds.n_features * ds.width;
    let opts = SolveOptions::default();

    let mut seq = SequentialCluster::new(driver::build_workers(&ds, &cfg).unwrap(), dim);
    let res_sync = admm::solve(&mut seq, dim, &cfg, Some(&ds), &opts).unwrap();

    let ccfg = full_barrier(25);
    let mut asy = AsyncCluster::new(driver::build_workers(&ds, &cfg).unwrap(), dim, &ccfg);
    let res_async = admm::solve(&mut asy, dim, &cfg, Some(&ds), &opts).unwrap();

    assert_eq!(res_sync.iters, res_async.iters, "termination must agree");
    assert_eq!(res_sync.converged, res_async.converged);
    assert_eq!(res_sync.z, res_async.z, "consensus iterate must be bit-identical");
    assert_eq!(res_sync.x, res_async.x, "extracted solution must be bit-identical");
    assert_eq!(res_sync.support, res_async.support);
    for (a, b) in res_sync.trace.records.iter().zip(&res_async.trace.records) {
        assert_eq!(a.primal, b.primal, "iter {}: primal residual drifted", a.iter);
        assert_eq!(a.dual, b.dual);
        assert_eq!(a.bilinear, b.bilinear);
        assert_eq!(b.participants, 3);
        assert_eq!(b.max_lag, 0, "full barrier must never fold stale replies");
    }
    // identical protocol volume, and strictly zero resync traffic
    assert_eq!(
        res_sync.transfers.net_down_bytes,
        res_async.transfers.net_down_bytes
    );
    assert_eq!(
        res_sync.transfers.net_up_bytes,
        res_async.transfers.net_up_bytes
    );
    assert_eq!(res_async.transfers.net_resync_bytes, 0);
    let stats = res_async.coordination.expect("async run must report stats");
    assert_eq!(stats.rounds as usize, res_async.iters);
    assert_eq!(stats.drops, 0);
    assert_eq!(stats.deaths, 0);
}

/// Acceptance: a node dies mid-solve and the fit still converges on the
/// quorum, with the dead shard marked degraded.
#[test]
fn crash_mid_solve_converges_on_the_quorum() {
    let (ds, mut cfg) = regression_fixture(3);
    cfg.solver.max_iters = 400;
    cfg.coordinator.coordination = CoordinationKind::Async;
    cfg.coordinator.quorum = 0.6;
    cfg.coordinator.max_staleness = 1;
    cfg.coordinator.heartbeat_ms = 10;
    cfg.coordinator.faults = FaultSpec::default().crash(1, 4);

    let dim = ds.n_features * ds.width;
    let workers = driver::build_workers(&ds, &cfg).unwrap();
    let mut cluster = AsyncCluster::new(workers, dim, &cfg.coordinator);
    let res = admm::solve(&mut cluster, dim, &cfg, Some(&ds), &SolveOptions::default()).unwrap();

    assert!(
        res.converged,
        "must converge on the surviving quorum ({} iters)",
        res.iters
    );
    assert_eq!(cluster.degraded(), vec![1], "crashed shard must be degraded");
    let stats = res.coordination.unwrap();
    assert_eq!(stats.deaths, 1);
    // the survivors' data still pins most of the planted support
    let f1 = support_f1(&res.support, &ds.support_true);
    assert!(f1 > 0.6, "support recovery collapsed after the crash: f1 = {f1}");
    // late rounds must run on the 2-node quorum
    let last = res.trace.last().unwrap();
    assert_eq!(last.participants, 2);
}

/// Acceptance: under a 16x slow node, async rounds finish in less
/// wall-clock than the full barrier (same fault model, same horizon).
#[test]
fn async_beats_full_barrier_under_a_16x_straggler() {
    let opts = StragglerOpts {
        iters: 8,
        base_ms: 4.0,
        ..Default::default()
    };
    let sync = run_point(&opts, 16, 1.0, 0).unwrap();
    let asy = run_point(&opts, 16, opts.quorum, opts.max_staleness).unwrap();
    // the barrier pays the straggler's 60 ms every round (>= 0.4 s over 8
    // rounds); the partial barrier proceeds on the two fast nodes
    assert!(
        sync.wall_seconds > 0.2,
        "sync run too fast ({:.3} s) — straggler delay not injected?",
        sync.wall_seconds
    );
    assert!(
        asy.wall_seconds * 2.0 < sync.wall_seconds,
        "async ({:.3} s) must be well under sync ({:.3} s) with a 16x straggler",
        asy.wall_seconds,
        sync.wall_seconds
    );
    // both ran the same fixed horizon
    assert_eq!(sync.stats.rounds, 8);
    assert_eq!(asy.stats.rounds, 8);
}

/// A milder straggler exercises the fold/resync machinery itself: late
/// replies within the bound are folded, deeper ones dropped and resynced.
#[test]
fn straggler_replies_fold_within_the_staleness_bound() {
    let opts = StragglerOpts {
        iters: 30,
        base_ms: 2.0,
        quorum: 0.5,
        max_staleness: 2,
        ..Default::default()
    };
    let p = run_point(&opts, 2, opts.quorum, opts.max_staleness).unwrap();
    let folded: u64 = p.stats.staleness_hist.iter().sum();
    assert!(folded > 0, "no replies folded at all");
    let straggler_folds = p.stats.participation.first().copied().unwrap_or(0);
    let stale_or_dropped = p.stats.staleness_hist.iter().skip(1).sum::<u64>() + p.stats.drops;
    assert!(
        straggler_folds > 0 || stale_or_dropped > 0 || p.stats.resyncs > 0,
        "a 2x straggler over 30 rounds should surface in the protocol stats: {}",
        p.stats.summary()
    );
    assert_eq!(p.stats.deaths, 0, "a slow node is not a dead node");
}

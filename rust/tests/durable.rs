//! Durable control-plane integration tests: journal replay across daemon
//! restarts, torn-tail and corrupted-journal handling at the serve level,
//! kill -9 recovery with bit-identical artifacts over real subprocess
//! coordinators, SIGTERM drain semantics, and the reproducibility of the
//! `psfit chaos --coordinator` kill schedule.

use std::path::PathBuf;
use std::time::Duration;

use psfit::network::socket::spawn_local_worker;
use psfit::network::socket::wire::JobSpec;
use psfit::network::socket::worker::spawn_flaky_worker;
use psfit::serve::journal::{self, Journal, JOURNAL_FILE};
use psfit::serve::{spawn_serve, JobPhase, ServeClient, ServeOpts};

fn state_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("psfit-durable-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn prediction_bits(client: &mut ServeClient, job: u64, q: &[(u32, f64)]) -> Vec<u64> {
    client
        .predict(job, q)
        .unwrap()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

const PROBE: [(u32, f64); 3] = [(0, 1.0), (3, -0.5), (7, 2.0)];

#[test]
fn a_restarted_daemon_serves_replayed_models_bit_identically() {
    let dir = state_dir("replay");
    let opts = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec![spawn_local_worker().unwrap(), spawn_local_worker().unwrap()],
        state_dir: Some(dir.display().to_string()),
        ..Default::default()
    };
    let addr = spawn_serve(&opts).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let spec = JobSpec {
        n: 48,
        m: 320,
        nodes: 2,
        ..Default::default()
    };
    let job = client.submit("replayed", spec).unwrap();
    let st = client.wait(job, Duration::from_secs(120)).unwrap();
    assert_eq!(st.phase, JobPhase::Done.code());
    let before = prediction_bits(&mut client, job, &PROBE);
    assert!(journal::model_blob_path(&dir, job).exists());

    // a second daemon over the same state dir replays the journal and must
    // serve the same artifact bit-for-bit, stats included
    let addr2 = spawn_serve(&opts).unwrap();
    let mut client2 = ServeClient::connect(&addr2).unwrap();
    assert_eq!(prediction_bits(&mut client2, job, &PROBE), before);
    let st2 = client2.status(job).unwrap();
    assert_eq!(st2.phase, JobPhase::Done.code());
    assert_eq!(st2.objective.to_bits(), st.objective.to_bits());
    assert_eq!(st2.iters, st.iters);
    assert_eq!(st2.support_len, st.support_len);
    let jobs = client2.jobs().unwrap();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].name, "replayed");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_failed_job_replays_with_its_failure_detail() {
    let dir = state_dir("failed");
    let opts = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec![spawn_flaky_worker(1).unwrap(), spawn_flaky_worker(1).unwrap()],
        state_dir: Some(dir.display().to_string()),
        ..Default::default()
    };
    let addr = spawn_serve(&opts).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let spec = JobSpec {
        n: 24,
        m: 120,
        nodes: 2,
        ..Default::default()
    };
    let job = client.submit("doomed", spec).unwrap();
    let err = client.wait(job, Duration::from_secs(60)).unwrap_err();
    assert!(err.to_string().contains("failed"), "{err}");

    // the restarted daemon never needs to re-dial anything for a failed
    // job, so a dead fleet address proves the phase + detail come straight
    // from the journal
    let opts2 = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec!["127.0.0.1:9".to_string()],
        state_dir: Some(dir.display().to_string()),
        ..Default::default()
    };
    let addr2 = spawn_serve(&opts2).unwrap();
    let mut client2 = ServeClient::connect(&addr2).unwrap();
    let jobs = client2.jobs().unwrap();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].phase, JobPhase::Failed.code());
    assert!(
        jobs[0].message.contains("death"),
        "summary lost the failure detail: {:?}",
        jobs[0].message
    );
    let st = client2.status(job).unwrap();
    assert!(st.message.contains("death"), "{}", st.message);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_torn_journal_tail_is_dropped_and_the_torn_job_reruns() {
    let dir = state_dir("torn");
    let spec = JobSpec {
        n: 32,
        m: 160,
        nodes: 2,
        ..Default::default()
    };
    {
        let (mut j, _) = Journal::open(&dir).unwrap();
        j.record_submit(1, "torn-tail", &spec).unwrap();
    }
    // simulate a crash mid-append: a length prefix promising 64 bytes with
    // only 5 behind it
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(dir.join(JOURNAL_FILE))
            .unwrap();
        f.write_all(&64u32.to_le_bytes()).unwrap();
        f.write_all(b"tornx").unwrap();
    }
    let opts = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec![spawn_local_worker().unwrap(), spawn_local_worker().unwrap()],
        state_dir: Some(dir.display().to_string()),
        ..Default::default()
    };
    let addr = spawn_serve(&opts).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    // the submit survived, the ragged tail did not, and recovery runs the
    // journaled-but-never-finished job to completion
    let st = client.wait(1, Duration::from_secs(120)).unwrap();
    assert_eq!(st.phase, JobPhase::Done.code());
    assert!(!prediction_bits(&mut client, 1, &PROBE).is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_corrupted_journal_refuses_to_start_with_a_named_error() {
    let dir = state_dir("corrupt");
    {
        let (mut j, _) = Journal::open(&dir).unwrap();
        j.record_submit(1, "a", &JobSpec::default()).unwrap();
        j.record_submit(2, "b", &JobSpec::default()).unwrap();
    }
    let path = dir.join(JOURNAL_FILE);
    let mut bytes = std::fs::read(&path).unwrap();
    // flip a bit inside the first record's payload — mid-log damage, not a
    // torn tail, so startup must refuse rather than serve a wrong table
    bytes[16] ^= 0x20;
    std::fs::write(&path, &bytes).unwrap();
    let opts = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec!["127.0.0.1:9".to_string()],
        state_dir: Some(dir.display().to_string()),
        ..Default::default()
    };
    let err = spawn_serve(&opts).unwrap_err().to_string();
    assert!(err.contains("JournalCorrupt"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- subprocess coordinators (kill -9 / SIGTERM need a real process) ----

#[cfg(unix)]
mod subprocess {
    use super::*;
    use std::path::Path;
    use std::process::{Child, Command, Stdio};
    use std::time::Instant;

    const BIN: &str = env!("CARGO_BIN_EXE_psfit");

    /// Kill-on-drop guard so a failed assertion leaves no daemon behind.
    struct Guard(Option<Child>);

    impl Drop for Guard {
        fn drop(&mut self) {
            if let Some(mut c) = self.0.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }

    fn spawn_serve_process(workers: &str, dir: &Path, listen: &str, log: &Path) -> Guard {
        let out = std::fs::File::create(log).unwrap();
        let err = out.try_clone().unwrap();
        Guard(Some(
            Command::new(BIN)
                .args([
                    "serve",
                    "--listen",
                    listen,
                    "--workers",
                    workers,
                    "--state-dir",
                    &dir.display().to_string(),
                    "--drain-grace-ms",
                    "2000",
                ])
                .stdin(Stdio::null())
                .stdout(Stdio::from(out))
                .stderr(Stdio::from(err))
                .spawn()
                .unwrap(),
        ))
    }

    fn await_line(log: &Path, needle: &str) -> String {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Ok(text) = std::fs::read_to_string(log) {
                for line in text.lines() {
                    if let Some(rest) = line.strip_prefix(needle) {
                        return rest.split_whitespace().next().unwrap_or("").to_string();
                    }
                }
            }
            assert!(
                Instant::now() < deadline,
                "`{needle}` never appeared in {}",
                log.display()
            );
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    fn log_contains(log: &Path, needle: &str) -> bool {
        std::fs::read_to_string(log)
            .map(|t| t.contains(needle))
            .unwrap_or(false)
    }

    /// Two jobs pinned to an exact round count: what the kill interrupts
    /// and what the uninterrupted reference runs.
    fn pinned_spec() -> JobSpec {
        let mut cfg = psfit::config::Config::default();
        cfg.solver.tol_primal = 0.0;
        cfg.solver.tol_dual = 0.0;
        cfg.solver.tol_bilinear = 0.0;
        cfg.solver.max_iters = 600;
        JobSpec {
            n: 64,
            m: 480,
            nodes: 2,
            seed: 4242,
            kappa: 10,
            config: cfg.to_json().to_string(),
            ..Default::default()
        }
    }

    #[test]
    fn kill_nine_mid_fit_then_restart_recovers_bit_identically() {
        let w1 = spawn_local_worker().unwrap();
        let w2 = spawn_local_worker().unwrap();
        let fleet = format!("{w1},{w2}");
        let scratch = state_dir("kill9");
        std::fs::create_dir_all(&scratch).unwrap();

        // uninterrupted reference: same spec through an in-process daemon
        let ref_dir = scratch.join("state-ref");
        let ref_addr = spawn_serve(&ServeOpts {
            listen: "127.0.0.1:0".to_string(),
            workers: fleet.split(',').map(String::from).collect(),
            state_dir: Some(ref_dir.display().to_string()),
            ..Default::default()
        })
        .unwrap();
        let mut ref_client = ServeClient::connect(&ref_addr).unwrap();
        let job = ref_client.submit("reference", pinned_spec()).unwrap();
        let st = ref_client.wait(job, Duration::from_secs(180)).unwrap();
        assert_eq!(st.phase, JobPhase::Done.code());
        let want = prediction_bits(&mut ref_client, job, &PROBE);

        // chaos run: subprocess coordinator, SIGKILLed mid-fit
        let chaos_dir = scratch.join("state-chaos");
        let log1 = scratch.join("serve1.log");
        let mut daemon = spawn_serve_process(&fleet, &chaos_dir, "127.0.0.1:0", &log1);
        let addr = await_line(&log1, "psfit serve listening on ");
        let mut client = ServeClient::connect(&addr).unwrap();
        assert_eq!(client.submit("interrupted", pinned_spec()).unwrap(), 1);
        std::thread::sleep(Duration::from_millis(1200));
        {
            let child = daemon.0.as_mut().unwrap();
            child.kill().unwrap();
            let _ = child.wait();
        }
        let log2 = scratch.join("serve2.log");
        let daemon2 = spawn_serve_process(&fleet, &chaos_dir, &addr, &log2);
        await_line(&log2, "psfit serve listening on ");
        assert!(
            log_contains(&log2, "crash detected"),
            "restart misread a SIGKILL as a clean drain"
        );

        // the same client rides through the restart; the job lands done
        // with the reference's exact bits, from blob and over the wire
        let st = client.wait(1, Duration::from_secs(180)).unwrap();
        assert_eq!(st.phase, JobPhase::Done.code());
        assert!(client.reconnects() > 0, "restart was invisible to the client");
        assert_eq!(prediction_bits(&mut client, 1, &PROBE), want);
        let ref_blob = std::fs::read(journal::model_blob_path(&ref_dir, job)).unwrap();
        let chaos_blob = std::fs::read(journal::model_blob_path(&chaos_dir, 1)).unwrap();
        assert_eq!(ref_blob, chaos_blob, "PSM1 artifacts diverged");
        drop(daemon2);
        let _ = std::fs::remove_dir_all(&scratch);
    }

    #[test]
    fn sigterm_drains_cleanly_and_the_restart_sees_the_marker() {
        let fleet = spawn_local_worker().unwrap();
        let scratch = state_dir("drain");
        std::fs::create_dir_all(&scratch).unwrap();
        let dir = scratch.join("state");
        let log1 = scratch.join("serve1.log");
        let daemon = spawn_serve_process(&fleet, &dir, "127.0.0.1:0", &log1);
        let addr = await_line(&log1, "psfit serve listening on ");
        let mut client = ServeClient::connect(&addr).unwrap();
        let spec = JobSpec {
            n: 32,
            m: 160,
            nodes: 1,
            ..Default::default()
        };
        let job = client.submit("drained", spec).unwrap();
        let st = client.wait(job, Duration::from_secs(120)).unwrap();
        assert_eq!(st.phase, JobPhase::Done.code());

        let pid = daemon.0.as_ref().unwrap().id().to_string();
        let killed = Command::new("kill").args(["-TERM", &pid]).status().unwrap();
        assert!(killed.success());
        // the drain exits the process on its own; poll the log for proof
        let deadline = Instant::now() + Duration::from_secs(30);
        while !log_contains(&log1, "drained: clean shutdown") {
            assert!(Instant::now() < deadline, "drain never completed");
            std::thread::sleep(Duration::from_millis(50));
        }
        assert!(log_contains(&log1, "draining: rejecting new submits"));

        let log2 = scratch.join("serve2.log");
        let daemon2 = spawn_serve_process(&fleet, &dir, "127.0.0.1:0", &log2);
        let addr2 = await_line(&log2, "psfit serve listening on ");
        assert!(
            log_contains(&log2, "previous daemon drained cleanly"),
            "restart misread a drain as a crash"
        );
        // the drained daemon's finished work is still served
        let mut client2 = ServeClient::connect(&addr2).unwrap();
        assert_eq!(client2.status(1).unwrap().phase, JobPhase::Done.code());
        drop(daemon2);
        let _ = std::fs::remove_dir_all(&scratch);
    }

    #[test]
    fn coordinator_chaos_quick_fingerprint_is_reproducible() {
        let run = || {
            let out = Command::new(BIN)
                .args(["chaos", "--coordinator", "--quick", "--jobs", "1"])
                .output()
                .unwrap();
            let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
            assert!(
                out.status.success(),
                "chaos --coordinator failed:\n{stdout}\n{}",
                String::from_utf8_lossy(&out.stderr)
            );
            stdout
                .lines()
                .find(|l| l.starts_with("fingerprint:"))
                .expect("no fingerprint line")
                .to_string()
        };
        let first = run();
        let second = run();
        assert_eq!(first, second, "same seed must print the same schedule");
    }
}

//! Failure-injection tests: every misconfiguration must fail loudly with a
//! actionable message, never silently compute garbage.

use psfit::config::{BackendKind, Config};
use psfit::data::{FeaturePlan, SyntheticSpec};
use psfit::driver;
use psfit::losses::LossKind;
use psfit::runtime::Manifest;
use psfit::util::cli::Args;
use psfit::util::json::Json;

#[test]
fn invalid_solver_configs_are_rejected() {
    let ds = SyntheticSpec::regression(10, 40, 2).generate();
    for mutate in [
        (|c: &mut Config| c.solver.rho_c = 0.0) as fn(&mut Config),
        |c| c.solver.rho_b = -1.0,
        |c| c.solver.gamma = 0.0,
        |c| c.solver.kappa = 0,
        |c| c.solver.max_iters = 0,
        |c| c.solver.inner_iters = 0,
    ] {
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = 2;
        mutate(&mut cfg);
        assert!(
            driver::fit(&ds, &cfg).is_err(),
            "config mutation accepted: {cfg:?}"
        );
    }
}

#[test]
fn xla_backend_without_artifacts_errors_with_hint() {
    let ds = SyntheticSpec::regression(10, 40, 2).generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = 2;
    cfg.platform.backend = BackendKind::Xla;
    // point at an empty dir
    let dir = std::env::temp_dir().join("psfit_no_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::env::set_var("PSFIT_ARTIFACTS", &dir);
    let err = driver::fit(&ds, &cfg).unwrap_err().to_string();
    std::env::remove_var("PSFIT_ARTIFACTS");
    assert!(
        err.contains("manifest") || err.contains("artifacts"),
        "unhelpful error: {err}"
    );
}

#[test]
fn manifest_parse_failures_name_the_problem() {
    // missing required key
    let err = Manifest::parse(r#"{"tile_m": 128}"#).unwrap_err().to_string();
    assert!(err.contains("block_n") || err.contains("missing"), "{err}");
    // wrong dtype
    let bad = r#"{
      "fingerprint": "x", "tile_m": 8, "block_n": 8, "bm": 8,
      "cg_iters": 1, "newton_iters": 1, "classes": 2,
      "param_slots": {"size": 8},
      "artifacts": {"a": {"file": "a.hlo.txt",
        "inputs": [{"shape": [8], "dtype": "int32"}], "outputs": []}}
    }"#;
    let err = Manifest::parse(bad).unwrap_err().to_string();
    assert!(err.contains("f32"), "{err}");
}

#[test]
fn config_json_rejects_unknown_and_mistyped_keys() {
    for bad in [
        r#"{"solver": {"rho_zeta": 1.0}}"#,
        r#"{"solver": {"kappa": "ten"}}"#,
        r#"{"platform": {"backend": "cuda"}}"#,
        r#"{"loss": "perceptron"}"#,
        r#"{"unknown_section": {}}"#,
    ] {
        let v = Json::parse(bad).unwrap();
        assert!(Config::from_json(&v).is_err(), "accepted: {bad}");
    }
}

#[test]
fn cli_rejects_unknown_options_and_bad_values() {
    let a = Args::parse_from(["train".to_string(), "--bogus".to_string(), "7".to_string()])
        .unwrap();
    let _ = a.get("n", 5usize);
    assert!(a.reject_unknown().is_err());

    let a = Args::parse_from(["train".to_string(), "--n".to_string(), "x7".to_string()]).unwrap();
    assert!(a.get("n", 5usize).is_err());
}

#[test]
fn feature_plan_always_respects_block_width_bound() {
    // the plan must split into extra blocks rather than exceed block_n
    for (n, blocks, bn) in [(100, 1, 10), (1001, 2, 512), (7, 3, 2)] {
        let plan = FeaturePlan::new(n, blocks, bn);
        assert!(plan.ranges.iter().all(|&(_, w)| w <= bn), "{n},{blocks},{bn}");
        assert_eq!(plan.ranges.iter().map(|&(_, w)| w).sum::<usize>(), n);
    }
}

#[test]
fn softmax_classes_mismatch_is_caught_on_xla() {
    // the softmax artifact is lowered for `classes = 10`; asking the xla
    // backend to run k = 4 must fail at construction, not at solve time
    let dir = driver::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let mut spec = SyntheticSpec::regression(16, 60, 2);
    spec.task = psfit::data::Task::Multiclass { k: 4 };
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.platform.backend = BackendKind::Xla;
    cfg.loss = LossKind::Softmax;
    cfg.classes = 4;
    cfg.solver.kappa = 8;
    let err = driver::fit(&ds, &cfg).unwrap_err().to_string();
    assert!(err.contains("classes") || err.contains("width"), "{err}");
}

#[test]
fn dataset_spec_invariants_enforced() {
    let mut spec = SyntheticSpec::regression(10, 40, 2);
    spec.sparsity_level = 1.0; // kappa would be 0
    assert_eq!(spec.kappa(), 1, "kappa must clamp to >= 1");
    // nodes > samples is rejected
    let bad = SyntheticSpec::regression(10, 1, 2);
    let result = std::panic::catch_unwind(|| bad.generate());
    assert!(result.is_err());
}

//! Numerical-guardrail integration tests: a pathological penalty ending
//! in a structured `Diverged` on every transport, the poison-quarantine →
//! banish → rejoin cycle over sockets, and a serve job landing in the
//! `timed_out` phase — with a queryable best-so-far model — when its
//! config carries a deadline.

use std::time::Duration;

use psfit::admm::{solve, SolveError, SolveOptions};
use psfit::backend::BlockParams;
use psfit::config::{Config, TransportKind};
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::metrics::TransferLedger;
use psfit::network::socket::spawn_local_worker;
use psfit::network::socket::wire::JobSpec;
use psfit::network::{Cluster, NodeReply, WarmState};
use psfit::serve::{spawn_serve, JobPhase, ServeClient, ServeOpts};

/// A penalty that overflows `participants * rho_c` must end in
/// `SolveError::Diverged` within the watchdog window on every transport
/// — sequential, threaded, and socket — never in a silent full-budget
/// run or an opaque transport error.
#[test]
fn pathological_rho_diverges_structured_on_every_transport() {
    let spec = SyntheticSpec::regression(24, 140, 2);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 1e308;
    cfg.solver.max_iters = 400;

    let mut scenarios: Vec<(&str, Config, bool)> = vec![
        ("sequential", cfg.clone(), false),
        ("threaded", cfg.clone(), true),
    ];
    let mut socket_cfg = cfg.clone();
    socket_cfg.platform.transport = TransportKind::Socket;
    socket_cfg.platform.workers = vec![
        spawn_local_worker().unwrap(),
        spawn_local_worker().unwrap(),
    ];
    scenarios.push(("socket", socket_cfg, false));

    for (name, cfg, threaded) in &mut scenarios {
        let err = driver::fit_with_options(&ds, cfg, &SolveOptions::default(), *threaded)
            .expect_err(&format!("{name}: a 1e308 penalty must not succeed"));
        match err.downcast_ref::<SolveError>() {
            Some(SolveError::Diverged { round, .. }) => {
                assert!(
                    *round <= cfg.solver.watchdog_window,
                    "{name}: diverged at round {round}, after the watchdog window"
                );
            }
            None => panic!("{name}: expected SolveError::Diverged, got: {err:#}"),
        }
    }
}

/// Wrapper that poisons node 0's replies with NaN for the first
/// `poison_rounds` rounds — enough consecutive strikes to cross
/// `platform.quarantine_limit` and trigger a banish.
struct NodeZeroPoison {
    inner: Box<dyn Cluster>,
    poison_rounds: usize,
    round: usize,
}

impl Cluster for NodeZeroPoison {
    fn nodes(&self) -> usize {
        self.inner.nodes()
    }
    fn round(&mut self, z: &[f64]) -> anyhow::Result<Vec<NodeReply>> {
        let mut replies = self.inner.round(z)?;
        if self.round < self.poison_rounds {
            for r in &mut replies {
                if r.node == 0 {
                    if let Some(v) = r.x.first_mut() {
                        *v = f64::NAN;
                    }
                }
            }
        }
        self.round += 1;
        Ok(replies)
    }
    fn loss_value(&mut self) -> anyhow::Result<f64> {
        self.inner.loss_value()
    }
    fn ledger(&mut self) -> TransferLedger {
        self.inner.ledger()
    }
    fn recycle(&mut self, replies: Vec<NodeReply>) {
        self.inner.recycle(replies)
    }
    fn coordination(&self) -> Option<psfit::metrics::CoordinationStats> {
        self.inner.coordination()
    }
    fn export_warm(&mut self) -> anyhow::Result<Vec<WarmState>> {
        self.inner.export_warm()
    }
    fn reseed(&mut self, states: &[WarmState], params: BlockParams) -> anyhow::Result<()> {
        self.inner.reseed(states, params)
    }
    fn banish(&mut self, node: usize, why: &str) {
        self.inner.banish(node, why)
    }
}

/// The full escalation cycle over the socket transport: repeated poison
/// from one node is quarantined round by round, crosses the strike limit
/// into a structured banish (a peer death), and — with `platform.rejoin`
/// on — the banished worker is re-admitted and finishes the fit with the
/// full roster.
#[test]
fn quarantined_repeat_offender_is_banished_then_rejoins() {
    let spec = SyntheticSpec::regression(32, 180, 2);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 14;
    cfg.solver.tol_primal = 0.0; // fixed horizon: the cycle lands mid-run
    cfg.platform.quarantine_limit = 2;
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.rejoin = true;
    cfg.platform.read_timeout_ms = 10_000;
    cfg.platform.workers = vec![
        spawn_local_worker().unwrap(),
        spawn_local_worker().unwrap(),
    ];

    let inner = driver::build_transport_cluster(&ds, &cfg, false).unwrap();
    let mut cluster = NodeZeroPoison {
        inner,
        poison_rounds: 2, // strikes 1 and 2: banished at the limit
        round: 0,
    };
    let res = solve(
        &mut cluster,
        ds.n_features * ds.width,
        &cfg,
        Some(&ds),
        &SolveOptions::default(),
    )
    .unwrap();

    assert_eq!(res.iters, 14, "healing keeps the full horizon");
    let stats = res.coordination.expect("socket cluster reports stats");
    assert_eq!(stats.quarantined, 2, "both poisoned replies were quarantined");
    assert!(stats.deaths >= 1, "the banish registers as a peer death");
    assert!(stats.rejoins >= 1, "the banished worker was re-admitted");
    let healed = res
        .trace
        .records
        .iter()
        .any(|r| r.iter > 3 && r.participants == 2);
    assert!(healed, "no post-banish round ran with the full roster");
}

/// A serve job whose config carries `solver.deadline_ms` lands in the
/// `timed_out` phase — a terminal success with a queryable best-so-far
/// model — not in `failed`.
#[test]
fn a_serve_job_with_a_deadline_lands_in_timed_out_with_a_model() {
    let opts = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec![spawn_local_worker().unwrap(), spawn_local_worker().unwrap()],
        ..Default::default()
    };
    let addr = spawn_serve(&opts).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();

    let mut jcfg = Config::default();
    jcfg.solver.deadline_ms = 1;
    jcfg.solver.tol_primal = 0.0; // never converges on tolerance
    jcfg.solver.max_iters = 2_000_000;
    let spec = JobSpec {
        n: 24,
        m: 120,
        nodes: 2,
        config: jcfg.to_json().to_string(),
        ..JobSpec::default()
    };
    let job = client.submit("deadlined", spec).unwrap();
    let st = client.wait(job, Duration::from_secs(60)).unwrap();
    assert_eq!(st.phase, JobPhase::TimedOut.code(), "{}", st.message);
    assert!(!st.converged);
    assert!(st.iters >= 1, "at least one round completed");
    assert!(st.support_len > 0, "best-so-far model is queryable");

    // the jobs table shows the terminal phase, and predict works
    let jobs = client.jobs().unwrap();
    assert_eq!(jobs[0].phase, JobPhase::TimedOut.code());
    let values = client.predict(job, &[(0, 1.0), (3, -0.5)]).unwrap();
    assert_eq!(values.len(), 1);
    assert!(values[0].is_finite());

    // and a non-finite query is rejected client-side
    let err = client
        .predict(job, &[(2, f64::NAN)])
        .unwrap_err()
        .to_string();
    assert!(err.contains("non-finite"), "{err}");
}

//! Self-healing integration tests: a dead worker rejoining mid-fit, a
//! checkpointed fit resuming bit-identically over the socket transport,
//! and a serve job landing in the `failed` phase — with death details —
//! when its fleet collapses below quorum.

use std::time::Duration;

use psfit::admm::SolveOptions;
use psfit::config::{Config, TransportKind};
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::network::socket::spawn_local_worker;
use psfit::network::socket::wire::JobSpec;
use psfit::network::socket::worker::spawn_flaky_worker;
use psfit::serve::{spawn_serve, JobPhase, ServeClient, ServeOpts};

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn a_flaky_worker_rejoins_mid_fit_and_the_roster_heals() {
    let spec = SyntheticSpec::regression(32, 180, 3);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 3;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 10;
    cfg.solver.tol_primal = 0.0; // fixed horizon: deaths and rejoins land mid-run
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.rejoin = true;
    cfg.platform.workers = vec![
        spawn_local_worker().unwrap(),
        spawn_local_worker().unwrap(),
        spawn_flaky_worker(2).unwrap(),
    ];
    let res = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();
    assert_eq!(res.iters, 10, "healing keeps the full horizon");
    let stats = res.coordination.expect("socket cluster reports stats");
    // the flaky worker's listener survives its session crashes, so every
    // death is answered by a successful next-round redial — and the fresh
    // session then dies again two rounds later, repeating the cycle
    assert!(stats.deaths >= 2, "deaths: {}", stats.deaths);
    assert!(stats.rejoins >= 2, "rejoins: {}", stats.rejoins);
    let healed = res
        .trace
        .records
        .iter()
        .any(|r| r.iter > 2 && r.participants == 3);
    assert!(healed, "no post-death round ran with the full roster");
    assert!(
        res.transfers.net_resync_bytes > 0,
        "rejoin traffic is ledgered as resync bytes"
    );
}

#[test]
fn a_checkpointed_socket_fit_resumes_bit_identically() {
    let spec = SyntheticSpec::regression(32, 160, 2);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 10;
    cfg.solver.tol_primal = 0.0;
    // uninterrupted reference on the local sequential transport (the
    // socket transport matches it bit-for-bit; see tests/socket.rs)
    let reference = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();

    let path = std::env::temp_dir().join("psfit_heal_resume.psf");
    let _ = std::fs::remove_file(&path);
    let fleet: Vec<String> = (0..2).map(|_| spawn_local_worker().unwrap()).collect();
    let mut scfg = cfg.clone();
    scfg.platform.transport = TransportKind::Socket;
    scfg.platform.workers = fleet;
    scfg.solver.checkpoint = path.to_string_lossy().into_owned();
    scfg.solver.checkpoint_every = 3;

    // "killed" coordinator: budget capped at 5 rounds, last snapshot at 3
    let mut interrupted = scfg.clone();
    interrupted.solver.max_iters = 5;
    let partial =
        driver::fit_with_options(&ds, &interrupted, &SolveOptions::default(), false).unwrap();
    assert_eq!(partial.iters, 5);
    assert!(path.exists(), "mid-fit snapshot written");

    // resume with the full budget over fresh connections: picks up at
    // iteration 3 and must replay the reference trajectory exactly
    let resumed = driver::fit_with_options(&ds, &scfg, &SolveOptions::default(), false).unwrap();
    assert_eq!(resumed.iters, 10);
    assert_eq!(resumed.trace.records.len(), reference.trace.records.len());
    for (a, b) in resumed.trace.records.iter().zip(&reference.trace.records) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.primal.to_bits(), b.primal.to_bits(), "iter {}", a.iter);
        assert_eq!(a.dual.to_bits(), b.dual.to_bits(), "iter {}", a.iter);
        assert_eq!(a.bilinear.to_bits(), b.bilinear.to_bits(), "iter {}", a.iter);
    }
    assert_eq!(bits(&resumed.x), bits(&reference.x));
    assert_eq!(bits(&resumed.z), bits(&reference.z));
    assert_eq!(resumed.support, reference.support);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn a_serve_job_fails_with_death_details_when_the_fleet_dies() {
    // every worker drops its session after one round: the job cannot
    // hold a quorum past round 2 and must land in the `failed` phase
    let opts = ServeOpts {
        listen: "127.0.0.1:0".to_string(),
        workers: vec![spawn_flaky_worker(1).unwrap(), spawn_flaky_worker(1).unwrap()],
        ..Default::default()
    };
    let addr = spawn_serve(&opts).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let spec = JobSpec {
        n: 24,
        m: 120,
        nodes: 2,
        ..JobSpec::default()
    };
    let job = client.submit("doomed", spec).unwrap();
    let err = client
        .wait(job, Duration::from_secs(60))
        .unwrap_err()
        .to_string();
    assert!(err.contains("failed"), "{err}");
    assert!(err.contains("death"), "{err}");
    // the job table remembers the failure and its cause
    let jobs = client.jobs().unwrap();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].phase, JobPhase::Failed.code());
    let st = client.status(job).unwrap();
    assert!(st.message.contains("death"), "{}", st.message);
}

//! Integration tests: whole-system behaviour on the native backend
//! (fast; the XLA path is covered by tests/backend_parity.rs).

use psfit::baselines::{best_subset_bnb, iht, lasso_path, BnbStatus};
use psfit::config::Config;
use psfit::data::{SyntheticSpec, Task};
use psfit::driver;
use psfit::losses::{make_loss, LossKind};
use psfit::sparsity::support_f1;

fn base(n: usize, m: usize, nodes: usize, sl: f64) -> (SyntheticSpec, Config) {
    let mut spec = SyntheticSpec::regression(n, m, nodes);
    spec.sparsity_level = sl;
    spec.noise_std = 0.05;
    let mut cfg = Config::default();
    cfg.platform.nodes = nodes;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.rho_c = 1.0;
    cfg.solver.rho_b = 0.5;
    cfg.solver.max_iters = 300;
    (spec, cfg)
}

#[test]
fn regression_recovers_support_across_node_counts() {
    for nodes in [1, 2, 5] {
        let (mut spec, cfg) = base(60, 600, nodes, 0.9);
        spec.noise_std = 0.02;
        let ds = spec.generate();
        let res = driver::fit(&ds, &cfg).unwrap();
        let f1 = support_f1(&res.support, &ds.support_true);
        assert!(f1 > 0.85, "nodes={nodes}: f1={f1}");
        assert_eq!(res.support.len(), spec.kappa());
    }
}

#[test]
fn logistic_and_hinge_converge_and_select_features() {
    for loss in [LossKind::Logistic, LossKind::Hinge] {
        let (mut spec, mut cfg) = base(48, 800, 2, 0.875);
        spec.task = Task::Binary;
        spec.noise_std = 0.1;
        cfg.loss = loss;
        cfg.solver.max_iters = 150;
        let ds = spec.generate();
        let res = driver::fit(&ds, &cfg).unwrap();
        let f1 = support_f1(&res.support, &ds.support_true);
        assert!(f1 > 0.6, "{loss:?}: f1={f1}");
    }
}

#[test]
fn softmax_multiclass_runs_native() {
    let (mut spec, mut cfg) = base(32, 400, 2, 0.75);
    spec.task = Task::Multiclass { k: 4 };
    cfg.loss = LossKind::Softmax;
    cfg.classes = 4;
    cfg.solver.kappa = spec.kappa() * 4;
    cfg.solver.max_iters = 60;
    let ds = spec.generate();
    let res = driver::fit(&ds, &cfg).unwrap();
    let f1 = support_f1(&res.support, &ds.support_true);
    assert!(f1 > 0.5, "f1={f1}");
}

#[test]
fn more_nodes_same_data_same_answer() {
    // consensus invariance: the distributed split must not change the
    // recovered model (same total data, different shardings)
    let (spec1, cfg1) = base(40, 480, 2, 0.9);
    let ds1 = spec1.generate();
    let res1 = driver::fit(&ds1, &cfg1).unwrap();

    let (mut spec2, mut cfg2) = base(40, 480, 4, 0.9);
    spec2.seed = spec1.seed; // same global generator stream
    cfg2.platform.nodes = 4;
    let ds2 = spec2.generate();
    let res2 = driver::fit(&ds2, &cfg2).unwrap();

    // shards differ (per-node normalization), but both must find the truth
    let f1_1 = support_f1(&res1.support, &ds1.support_true);
    let f1_2 = support_f1(&res2.support, &ds2.support_true);
    assert!(f1_1 > 0.85 && f1_2 > 0.85, "{f1_1} vs {f1_2}");
}

#[test]
fn rho_b_controls_bilinear_residual() {
    // Figure 1's qualitative claim, as a test: larger rho_b drives the
    // bilinear residual down faster, while primal/dual stay comparable.
    let (spec, mut cfg) = base(60, 600, 4, 0.8);
    let ds = spec.generate();
    cfg.solver.max_iters = 30;
    cfg.solver.tol_primal = 0.0; // fixed horizon

    let mut finals = Vec::new();
    for rho_b in [0.5, 4.0] {
        cfg.solver.rho_b = rho_b;
        cfg.solver.rho_c = 2.0 * rho_b;
        cfg.solver.rho_l = cfg.solver.rho_c;
        let res = driver::fit(&ds, &cfg).unwrap();
        finals.push(res.trace.last().unwrap().bilinear);
    }
    assert!(
        finals[1] < finals[0],
        "bilinear residual should drop faster with larger rho_b: {finals:?}"
    );
}

#[test]
fn objective_beats_lasso_and_iht_matches_bnb_on_easy_problem() {
    let (spec, mut cfg) = base(30, 400, 2, 0.9);
    cfg.solver.polish = true;
    let ds = spec.generate();
    let kappa = spec.kappa();
    let res = driver::fit(&ds, &cfg).unwrap();

    let (a, b) = ds.stacked();
    let loss = make_loss(LossKind::Squared, 1);
    let obj_admm = psfit::admm::solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &res.x);

    // exact best subset
    let bnb = best_subset_bnb(&a, &b, kappa, cfg.solver.gamma, 60.0);
    assert_eq!(bnb.status, BnbStatus::Optimal);
    // Bi-cADMM should land on (or extremely near) the exact optimum
    assert!(
        obj_admm <= bnb.objective * 1.02 + 1e-6,
        "admm {obj_admm} vs exact {}",
        bnb.objective
    );

    // lasso at the same support size has the l1 bias -> worse objective
    let lasso = lasso_path(&a, &b, kappa, 40, 200);
    let obj_lasso = psfit::admm::solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &lasso.x);
    assert!(
        obj_admm <= obj_lasso + 1e-9,
        "admm {obj_admm} vs lasso {obj_lasso}"
    );

    // IHT agrees on this easy instance
    let ih = iht(&a, &b, kappa, cfg.solver.gamma, 3000, 1e-10);
    assert_eq!(ih.support, bnb.support);
}

#[test]
fn termination_respects_tolerances() {
    let (spec, mut cfg) = base(40, 400, 2, 0.9);
    let ds = spec.generate();
    // loose tolerances stop much earlier than tight ones
    cfg.solver.tol_primal = 1e-2;
    cfg.solver.tol_dual = 1e-2;
    cfg.solver.tol_bilinear = 1e-1;
    let loose = driver::fit(&ds, &cfg).unwrap();
    cfg.solver.tol_primal = 1e-5;
    cfg.solver.tol_dual = 1e-5;
    cfg.solver.tol_bilinear = 1e-5;
    let tight = driver::fit(&ds, &cfg).unwrap();
    assert!(loose.iters < tight.iters, "{} vs {}", loose.iters, tight.iters);
    assert!(loose.converged);
}

#[test]
fn trace_csv_is_well_formed() {
    let (spec, mut cfg) = base(20, 200, 2, 0.9);
    cfg.solver.max_iters = 10;
    cfg.solver.tol_primal = 0.0;
    let ds = spec.generate();
    let res = driver::fit(&ds, &cfg).unwrap();
    let csv = res.trace.to_csv();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines[0], "iter,primal,dual,bilinear,wall,participants,max_lag");
    assert_eq!(lines.len(), 11); // header + 10 iterations
    for line in &lines[1..] {
        assert_eq!(line.split(',').count(), 7);
        // synchronous coordination: every node participates, nothing stale
        assert!(line.ends_with(",2,0"), "unexpected row: {line}");
    }
}

#[test]
fn config_json_file_roundtrip_drives_solver() {
    let dir = std::env::temp_dir().join("psfit_test_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    std::fs::write(
        &path,
        r#"{"solver": {"kappa": 4, "max_iters": 12, "tol_primal": 0.0}, "platform": {"nodes": 2}}"#,
    )
    .unwrap();
    let cfg = Config::from_json_file(&path).unwrap();
    assert_eq!(cfg.solver.kappa, 4);
    let spec = SyntheticSpec::regression(20, 100, 2);
    let ds = spec.generate();
    let res = driver::fit(&ds, &cfg).unwrap();
    assert_eq!(res.iters, 12);
    assert_eq!(res.support.len(), 4);
}

//! Kernel-layer invariants: tiled kernels match their naive reference
//! twins across random shapes (including non-multiple-of-tile dims and
//! empty/zero-row edge cases), stride views match packed copies, and
//! whole solves are bit-identical at any worker-pool width.

use psfit::config::Config;
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::linalg::kernels::{self, ColumnBlockView};
use psfit::linalg::Matrix;
use psfit::util::rng::Rng;
use psfit::util::testkit::{assert_close_f32, run_prop, PropConfig};

fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    m.for_each_mut(|v| *v = rng.normal_f32());
    m
}

fn randvec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal_f32(&mut v);
    v
}

/// Random shape with deliberate edge cases: zero rows, single row/col,
/// and sizes straddling the unroll width of 4.
fn rand_shape(rng: &mut Rng, size: usize) -> (usize, usize) {
    let rows = rng.below(2 * size + 3); // 0 included
    let cols = 1 + rng.below(size + 6);
    (rows, cols)
}

#[test]
fn prop_tiled_matvec_matches_naive() {
    run_prop("matvec_tiled", PropConfig::default(), |rng, size| {
        let (rows, cols) = rand_shape(rng, size);
        let a = randmat(rng, rows, cols);
        let x = randvec(rng, cols);
        let mut y0 = vec![0.0f32; rows];
        let mut y1 = vec![0.0f32; rows];
        kernels::matvec_naive(&a.view(), &x, &mut y0);
        kernels::matvec(&a.view(), &x, &mut y1);
        assert_close_f32(&y0, &y1, 1e-5)
    });
}

#[test]
fn prop_tiled_matvec_t_matches_naive() {
    run_prop("matvec_t_tiled", PropConfig::default(), |rng, size| {
        let (rows, cols) = rand_shape(rng, size);
        let a = randmat(rng, rows, cols);
        let mut v = randvec(rng, rows);
        if !v.is_empty() {
            v[0] = 0.0; // exercise the naive skip-zero branch
        }
        let mut y0 = vec![0.0f32; cols];
        let mut y1 = vec![0.0f32; cols];
        kernels::matvec_t_naive(&a.view(), &v, &mut y0);
        kernels::matvec_t(&a.view(), &v, &mut y1);
        assert_close_f32(&y0, &y1, 1e-5)
    });
}

#[test]
fn prop_tiled_gram_matches_naive_on_stride_views() {
    run_prop("gram_tiled", PropConfig::default(), |rng, size| {
        let (rows, cols) = rand_shape(rng, size);
        let a = randmat(rng, rows, cols);
        // random column block, read in place vs packed
        let w = 1 + rng.below(cols);
        let col0 = rng.below(cols - w + 1);
        let mut g0 = vec![0.0f32; w * w];
        let mut g1 = vec![0.0f32; w * w];
        kernels::gram_naive(&a.column_block(col0, w).view(), &mut g0);
        kernels::gram(&a.column_block_view(col0, w), &mut g1);
        assert_close_f32(&g0, &g1, 1e-5)
    });
}

#[test]
fn prop_multi_vector_kernels_match_naive() {
    run_prop("matmul_tiled", PropConfig::default(), |rng, size| {
        let (rows, cols) = rand_shape(rng, size);
        let k = 1 + rng.below(5);
        let a = randmat(rng, rows, cols);
        let x = randvec(rng, k * cols);
        let v = randvec(rng, k * rows);
        let mut y0 = vec![0.0f32; k * rows];
        let mut y1 = vec![0.0f32; k * rows];
        kernels::matmul_naive(&a.view(), &x, k, &mut y0);
        kernels::matmul(&a.view(), &x, k, &mut y1);
        assert_close_f32(&y0, &y1, 1e-5)?;
        let mut z0 = vec![0.0f32; k * cols];
        let mut z1 = vec![0.0f32; k * cols];
        kernels::matmul_t_naive(&a.view(), &v, k, &mut z0);
        kernels::matmul_t(&a.view(), &v, k, &mut z1);
        assert_close_f32(&z0, &z1, 1e-5)
    });
}

#[test]
fn zero_row_views_produce_zero_results() {
    let data: Vec<f32> = Vec::new();
    let a = ColumnBlockView::new(&data, 0, 3, 3, 0);
    let mut y = vec![7.0f32; 3];
    kernels::matvec_t(&a, &[], &mut y);
    assert_eq!(y, vec![0.0; 3]);
    let mut g = vec![0.0f32; 9];
    kernels::gram(&a, &mut g);
    kernels::gram_naive(&a, &mut g);
    assert!(g.iter().all(|&v| v == 0.0));
}

/// The acceptance pin: solver output is bit-identical between
/// `--threads 1` and `--threads N`.
#[test]
fn solver_output_bit_identical_across_thread_counts() {
    let ds = SyntheticSpec::regression(48, 160, 2).generate();
    let mut cfg = Config::default();
    cfg.solver.kappa = 10;
    cfg.solver.max_iters = 20;
    cfg.platform.devices_per_node = 4; // several blocks per node queue

    cfg.platform.threads = 1;
    let serial = driver::fit(&ds, &cfg).unwrap();
    for threads in [2, 4] {
        cfg.platform.threads = threads;
        let pooled = driver::fit(&ds, &cfg).unwrap();
        assert_eq!(serial.z, pooled.z, "threads={threads}");
        assert_eq!(serial.x, pooled.x, "threads={threads}");
        assert_eq!(serial.support, pooled.support, "threads={threads}");
        assert_eq!(serial.iters, pooled.iters, "threads={threads}");
    }
}

/// Multiclass (softmax) goes through the batched multi-RHS path; pin the
/// same determinism there.
#[test]
fn multiclass_solve_bit_identical_across_thread_counts() {
    use psfit::data::Task;
    use psfit::losses::LossKind;
    let mut spec = SyntheticSpec::regression(24, 90, 2);
    spec.task = Task::Multiclass { k: 3 };
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.loss = LossKind::Softmax;
    cfg.classes = 3;
    cfg.solver.kappa = 6;
    cfg.solver.max_iters = 8;
    cfg.platform.devices_per_node = 3;

    cfg.platform.threads = 1;
    let serial = driver::fit(&ds, &cfg).unwrap();
    cfg.platform.threads = 4;
    let pooled = driver::fit(&ds, &cfg).unwrap();
    assert_eq!(serial.z, pooled.z);
    assert_eq!(serial.x, pooled.x);
}

/// The in-place column views must leave a non-zero savings note in the
/// ledger for native solves.
#[test]
fn native_solve_reports_packing_bytes_saved() {
    let ds = SyntheticSpec::regression(16, 60, 2).generate();
    let mut cfg = Config::default();
    cfg.solver.kappa = 4;
    cfg.solver.max_iters = 3;
    let res = driver::fit(&ds, &cfg).unwrap();
    // every node reports its full shard: sum_i m_i * n * 4 bytes
    assert_eq!(res.transfers.host_copy_saved_bytes, 60 * 16 * 4);
}

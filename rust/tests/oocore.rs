//! Out-of-core data plane, pinned end to end: a fit from memory-mapped
//! `PSD1` shards must be *bit-identical* to the RAM-resident fit on every
//! transport (sequential, threaded, socket), `psfit convert` must
//! reproduce the resident load→resplit→storage-policy pipeline exactly
//! (dense and CSR), corrupted shard files must fail with named `psd1:`
//! errors, and mini-batch rounds must agree across transports from
//! mapped shards.

use std::path::PathBuf;

use psfit::admm::SolveOptions;
use psfit::config::{Config, TransportKind};
use psfit::data::{
    self, io, shardfile, ConvertInput, ConvertOptions, Dataset, SparseMode, SyntheticSpec,
};
use psfit::driver;
use psfit::util::testkit::{run_prop, PropConfig};

fn bits(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Write one `PSD1` file per shard (fit-time storage policy applied, so
/// the mapped twin holds exactly what the resident fit would compute on)
/// and open them back as a mapped dataset.
fn mapped_twin(ds: &Dataset, cfg: &Config, tag: &str) -> (Dataset, Vec<PathBuf>) {
    let base = std::env::temp_dir().join(format!("psfit_oocore_{tag}"));
    let mut paths = Vec::new();
    for (i, shard) in ds.shards.iter().enumerate() {
        let p = shardfile::shard_path(&base, i);
        let stored =
            shard.with_storage_policy(cfg.platform.sparse, cfg.platform.sparse_threshold);
        shardfile::write_shard(&stored, &p).unwrap();
        paths.push(p);
    }
    let mapped = shardfile::open_dataset(&paths).unwrap();
    for (m, r) in mapped.shards.iter().zip(&ds.shards) {
        assert!(m.data.is_mapped(), "twin shard is not mapped");
        assert_eq!(m.labels, r.labels);
    }
    (mapped, paths)
}

fn cleanup(paths: &[PathBuf]) {
    for p in paths {
        let _ = std::fs::remove_file(p);
    }
}

fn assert_same_fit(a: &psfit::admm::SolveResult, b: &psfit::admm::SolveResult, what: &str) {
    assert_eq!(a.support, b.support, "{what}: supports differ");
    assert_eq!(bits(&a.x), bits(&b.x), "{what}: x differs");
    assert_eq!(bits(&a.z), bits(&b.z), "{what}: z differs");
    assert_eq!(a.iters, b.iters, "{what}: iteration counts differ");
}

// ------------------------------------------------- local transport parity

/// Mapped vs resident on the sequential and threaded clusters, for both
/// storage layouts (a dense problem and a sparse one the auto policy
/// stores as CSR).
#[test]
fn mapped_fit_is_bit_identical_to_resident_on_local_transports() {
    for (density, tag) in [(1.0, "dense"), (0.05, "csr")] {
        let mut spec = SyntheticSpec::regression(20, 120, 2);
        spec.density = density;
        let ds = spec.generate();
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = 15;

        let (mapped, paths) = mapped_twin(&ds, &cfg, &format!("local_{tag}"));
        if density < 0.25 {
            assert!(
                mapped.shards.iter().all(|s| s.data.is_csr()),
                "sparse twin should map as CSR"
            );
        }
        for threaded in [false, true] {
            let opts = SolveOptions::default();
            let resident = driver::fit_with_options(&ds, &cfg, &opts, threaded).unwrap();
            let oo = driver::fit_with_options(&mapped, &cfg, &opts, threaded).unwrap();
            assert_same_fit(&resident, &oo, &format!("{tag}, threaded={threaded}"));
        }
        cleanup(&paths);
    }
}

// ------------------------------------------------------ socket transport

struct WorkerGuard(std::process::Child);
impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn spawn_worker() -> (WorkerGuard, String) {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    let mut child = Command::new(env!("CARGO_BIN_EXE_psfit"))
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn psfit worker");
    let stdout = child.stdout.take().unwrap();
    let guard = WorkerGuard(child);
    let mut line = String::new();
    std::io::BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .strip_prefix("psfit worker listening on ")
        .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
        .to_string();
    (guard, addr)
}

/// Mapped vs resident over real worker processes: the mapped shards ship
/// in their on-disk layout's wire form and the fleet reproduces the
/// sequential resident fit bit for bit.
#[test]
fn mapped_fit_is_bit_identical_over_the_socket_transport() {
    let mut spec = SyntheticSpec::regression(18, 96, 2);
    spec.density = 0.1; // auto policy -> CSR shards on both sides
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 12;

    let (mapped, paths) = mapped_twin(&ds, &cfg, "socket");
    let opts = SolveOptions::default();
    let reference = driver::fit_with_options(&ds, &cfg, &opts, false).unwrap();

    let (_g1, a1) = spawn_worker();
    let (_g2, a2) = spawn_worker();
    let mut sock_cfg = cfg.clone();
    sock_cfg.platform.transport = TransportKind::Socket;
    sock_cfg.platform.workers = vec![a1, a2];
    let sock_resident = driver::fit_with_options(&ds, &sock_cfg, &opts, false).unwrap();
    let sock_mapped = driver::fit_with_options(&mapped, &sock_cfg, &opts, false).unwrap();
    cleanup(&paths);

    assert_same_fit(&reference, &sock_resident, "socket resident vs sequential");
    assert_same_fit(&reference, &sock_mapped, "socket mapped vs sequential");
}

// ---------------------------------------------- mini-batch across transports

/// Seeded mini-batch rounds from mapped shards: the chunk schedule is a
/// pure function of (seed, round), so sequential, threaded, and socket
/// clusters must walk identical trajectories.
#[test]
fn minibatch_rounds_agree_across_transports_from_mapped_shards() {
    let mut spec = SyntheticSpec::regression(16, 112, 2);
    spec.density = 0.5;
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = 4;
    cfg.solver.max_iters = 10;
    cfg.solver.tol_primal = 0.0; // fixed rounds on every transport
    cfg.solver.minibatch = 16; // 56 rows/node -> 4 chunks
    cfg.solver.minibatch_seed = 5;

    let (mapped, paths) = mapped_twin(&ds, &cfg, "minibatch");
    let opts = SolveOptions::default();
    let seq = driver::fit_with_options(&mapped, &cfg, &opts, false).unwrap();
    let thr = driver::fit_with_options(&mapped, &cfg, &opts, true).unwrap();
    assert_same_fit(&seq, &thr, "minibatch threaded vs sequential");

    let (_g1, a1) = spawn_worker();
    let (_g2, a2) = spawn_worker();
    let mut sock_cfg = cfg.clone();
    sock_cfg.platform.transport = TransportKind::Socket;
    sock_cfg.platform.workers = vec![a1, a2];
    let sock = driver::fit_with_options(&mapped, &sock_cfg, &opts, false).unwrap();
    cleanup(&paths);
    assert_same_fit(&seq, &sock, "minibatch socket vs sequential");
}

// --------------------------------------------------- convert roundtrips

/// `psfit convert` must reproduce the resident pipeline (load → resplit →
/// storage policy) exactly: same rows, same labels, same stored values —
/// for dense, CSR, and auto-decided storage, across random shard counts.
#[test]
fn prop_convert_matches_resident_pipeline_dense_and_csr() {
    run_prop(
        "convert_roundtrip",
        PropConfig { cases: 24, ..Default::default() },
        |rng, size| {
            let n = 4 + size % 12;
            let m = 6 + rng.below(30);
            let mut spec = SyntheticSpec::regression(n, m, 1);
            spec.density = 0.2 + rng.uniform() * 0.8;
            spec.seed = rng.next_u64();
            let ds = spec.generate();

            let id = rng.next_u64();
            let svm = std::env::temp_dir().join(format!("psfit_oocore_prop_{id}.svm"));
            io::save_libsvm(&ds, &svm).map_err(|e| e.to_string())?;

            let nodes = 1 + rng.below(3.min(m));
            let mode = [SparseMode::Auto, SparseMode::Always, SparseMode::Never]
                [rng.below(3)];
            let base = std::env::temp_dir().join(format!("psfit_oocore_prop_{id}"));
            let opts = ConvertOptions {
                nodes,
                mode,
                threshold: 0.25,
                n_features: None,
                sanitize: false,
            };
            let summary = data::convert(&ConvertInput::Libsvm(svm.clone()), &base, &opts)
                .map_err(|e| e.to_string())?;
            let paths: Vec<PathBuf> = summary.shards.iter().map(|s| s.path.clone()).collect();
            let mapped = shardfile::open_dataset(&paths).map_err(|e| e.to_string())?;

            // resident reference: same file through the in-memory pipeline
            let mut resident = io::load_libsvm(&svm, None).map_err(|e| e.to_string())?;
            if nodes > 1 {
                resident = resident.resplit(nodes);
            }
            let _ = std::fs::remove_file(&svm);
            let check = (|| -> Result<(), String> {
                if mapped.nodes() != resident.nodes() {
                    return Err("shard count mismatch".into());
                }
                if mapped.n_features != resident.n_features {
                    return Err("feature count mismatch".into());
                }
                for (ms, rs) in mapped.shards.iter().zip(&resident.shards) {
                    let rs = rs.with_storage_policy(mode, 0.25);
                    if ms.labels != rs.labels {
                        return Err("labels mismatch".into());
                    }
                    if ms.data.is_csr() != rs.data.is_csr() {
                        return Err(format!(
                            "storage mismatch: {} vs {}",
                            ms.data.storage_name(),
                            rs.data.storage_name()
                        ));
                    }
                    // stored values must agree bit for bit, row by row
                    let md = ms.data.to_dense();
                    let rd = rs.data.to_dense();
                    for r in 0..ms.rows() {
                        if md.row(r).iter().map(|v| v.to_bits()).ne(
                            rd.row(r).iter().map(|v| v.to_bits()),
                        ) {
                            return Err(format!("row {r} values mismatch"));
                        }
                    }
                }
                Ok(())
            })();
            cleanup(&paths);
            check
        },
    );
}

// ------------------------------------------------------ corruption safety

/// Corrupted `PSD1` files must fail with stable named errors through the
/// public open path — never a panic, never a silent partial read.
#[test]
fn corrupted_psd1_files_fail_with_named_errors() {
    let mut spec = SyntheticSpec::regression(8, 24, 1);
    spec.density = 0.3;
    let ds = spec.generate();
    let base = std::env::temp_dir().join("psfit_oocore_corrupt");
    let p = shardfile::shard_path(&base, 0);
    shardfile::write_shard(&ds.shards[0], &p).unwrap();
    let good = std::fs::read(&p).unwrap();
    let open_err = |bytes: &[u8]| -> String {
        std::fs::write(&p, bytes).unwrap();
        shardfile::open_shard(&p).unwrap_err().to_string()
    };

    // truncated header
    assert!(open_err(&good[..40]).contains("psd1: truncated header"));
    // bad magic
    let mut b = good.clone();
    b[0] = b'X';
    assert!(open_err(&b).contains("psd1: bad magic"));
    // corrupted checksum field
    let mut b = good.clone();
    b[136] ^= 0xFF;
    assert!(open_err(&b).contains("psd1: header checksum mismatch"));
    // version bump (checksum re-sealed so the version check is reached)
    let mut b = good.clone();
    b[4] = 99;
    let sum = psfit::util::fnv1a(&b[..136]);
    b[136..144].copy_from_slice(&sum.to_le_bytes());
    assert!(open_err(&b).contains("psd1: unsupported version"));
    // truncated payload
    assert!(open_err(&good[..good.len() - 8]).contains("psd1: truncated file"));
    let _ = std::fs::remove_file(&p);
}

// ----------------------------------------------------------- CLI end to end

/// The full CLI loop: `psfit convert` emits shards, `psfit train --shards`
/// maps them, and the `--model-out` JSON (exact f64 bit patterns) is
/// byte-identical to the resident `--libsvm` fit's.
#[test]
fn model_out_json_is_byte_identical_for_mapped_and_resident_cli_fits() {
    use std::process::Command;

    let mut spec = SyntheticSpec::regression(12, 48, 1);
    spec.density = 0.6;
    let ds = spec.generate();
    let dir = std::env::temp_dir();
    let svm = dir.join("psfit_oocore_cli.svm");
    io::save_libsvm(&ds, &svm).unwrap();
    let base = dir.join("psfit_oocore_cli");
    let resident_json = dir.join("psfit_oocore_cli_resident.json");
    let mapped_json = dir.join("psfit_oocore_cli_mapped.json");

    let run = |args: &[&str]| {
        let out = Command::new(env!("CARGO_BIN_EXE_psfit"))
            .args(args)
            .output()
            .expect("run psfit");
        assert!(
            out.status.success(),
            "psfit {args:?} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        out
    };

    run(&[
        "convert",
        "--libsvm",
        svm.to_str().unwrap(),
        "--nodes",
        "2",
        "--out",
        base.to_str().unwrap(),
    ]);
    let shard0 = shardfile::shard_path(&base, 0);
    let shard1 = shardfile::shard_path(&base, 1);
    let shards_arg = format!("{},{}", shard0.display(), shard1.display());

    let common = ["--kappa", "3", "--iters", "10", "--minibatch", "8"];
    let mut a = vec![
        "train",
        "--libsvm",
        svm.to_str().unwrap(),
        "--nodes",
        "2",
        "--model-out",
        resident_json.to_str().unwrap(),
    ];
    a.extend_from_slice(&common);
    run(&a);
    let mut b = vec![
        "train",
        "--shards",
        shards_arg.as_str(),
        "--model-out",
        mapped_json.to_str().unwrap(),
    ];
    b.extend_from_slice(&common);
    let out = run(&b);
    // the mini-batch schedule fingerprint is printed and stable
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("schedule fingerprint 0x"),
        "no fingerprint line in:\n{stderr}"
    );

    let resident = std::fs::read(&resident_json).unwrap();
    let mapped = std::fs::read(&mapped_json).unwrap();
    assert!(!resident.is_empty());
    assert_eq!(
        resident, mapped,
        "model-out JSON differs between resident and mapped fits"
    );
    for p in [&svm, &shard0, &shard1, &resident_json, &mapped_json] {
        let _ = std::fs::remove_file(p);
    }
}

//! Integration tests of the sparsity-path subsystem: warm-vs-cold
//! parity, checkpoint kill/resume, factorization-reuse accounting, and
//! the warm-state plumbing on every transport.

use psfit::admm::SolveOptions;
use psfit::backend::native::{NativeBackend, SolveMode};
use psfit::backend::BlockParams;
use psfit::config::Config;
use psfit::coordinator::AsyncCluster;
use psfit::data::{FeaturePlan, SyntheticSpec};
use psfit::losses::Squared;
use psfit::network::{Cluster, NodeWorker, SequentialCluster, ThreadedCluster};
use psfit::path::run_path;
use psfit::sparsity::support_f1;
use psfit::util::testkit::{run_prop, PropConfig};

fn opts() -> SolveOptions {
    SolveOptions::default()
}

fn planted(n: usize, nodes: usize, seed: u64) -> (SyntheticSpec, Config) {
    let mut spec = SyntheticSpec::regression(n, 10 * n, nodes);
    spec.sparsity_level = 0.85;
    spec.noise_std = 0.01;
    spec.seed = seed;
    let mut cfg = Config::default();
    cfg.platform.nodes = nodes;
    cfg.solver.max_iters = 400;
    (spec, cfg)
}

/// Warm-started solve at kappa must reach the same support and objective
/// (within tolerance) as a cold solve at the same kappa — the path is a
/// faster route to the same models, not different models.
#[test]
fn warm_path_matches_cold_solve_prop() {
    run_prop(
        "warm_path_parity",
        PropConfig {
            cases: 5,
            seed: 0xA7,
            max_size: 12,
        },
        |rng, size| {
            let n = 18 + size;
            let (mut spec, mut cfg) = planted(n, 2, 0);
            spec.seed = rng.next_u64();
            let ds = spec.generate();
            let k2 = spec.kappa();
            let k1 = (2 * k2).min(n - 1);

            cfg.path.budgets = vec![k1, k2];
            let warm = run_path(&ds, &cfg, &opts(), false).map_err(|e| e.to_string())?;
            let mut cfg_cold = cfg.clone();
            cfg_cold.path.budgets = vec![k2];
            let cold = run_path(&ds, &cfg_cold, &opts(), false).map_err(|e| e.to_string())?;

            let pw = warm.trace.last().unwrap();
            let pc = cold.trace.last().unwrap();
            assert_eq!(pw.kappa, k2);
            if !pw.warm {
                return Err("second path point was not warm-started".into());
            }
            let f1 = support_f1(&pw.support, &pc.support);
            if f1 < 0.9 {
                return Err(format!("supports diverged: f1 {f1} (n {n}, k {k2})"));
            }
            let scale = 1.0f64.max(pc.objective.abs());
            if (pw.objective - pc.objective).abs() > 2e-2 * scale {
                return Err(format!(
                    "objectives diverged: warm {} vs cold {}",
                    pw.objective, pc.objective
                ));
            }
            Ok(())
        },
    );
}

/// Kill the sweep after point 1 (via the limit hook), resume from the
/// checkpoint, and require the remaining trace to be bit-identical to an
/// uninterrupted run: same iteration counts, supports, and objective
/// *bits* (wall-clock and rebuild counters are exempt — a resumed process
/// re-factors what the killed one held in memory).
#[test]
fn checkpoint_resume_is_bit_identical() {
    let (spec, mut cfg) = planted(30, 2, 7);
    let ds = spec.generate();
    let k = spec.kappa();
    cfg.path.budgets = vec![(3 * k).min(29), (2 * k).min(28), k];

    // uninterrupted reference (no checkpoint file involved)
    let full = run_path(&ds, &cfg, &opts(), false).unwrap();
    assert_eq!(full.trace.points.len(), 3);

    // killed sweep: stop after the first completed point
    let ck = std::env::temp_dir().join("psfit_path_resume.psc");
    let _ = std::fs::remove_file(&ck);
    cfg.path.checkpoint = Some(ck.to_string_lossy().into_owned());
    let mut cfg_kill = cfg.clone();
    cfg_kill.path.limit = Some(1);
    let part = run_path(&ds, &cfg_kill, &opts(), false).unwrap();
    assert_eq!(part.trace.points.len(), 1);
    assert!(ck.exists(), "checkpoint must be written after each point");

    // resume: skips point 1, replays points 2..3 from the saved state
    let resumed = run_path(&ds, &cfg, &opts(), false).unwrap();
    assert_eq!(resumed.resumed_points, 1);
    assert_eq!(resumed.trace.points.len(), 3);
    for (a, b) in full.trace.points.iter().zip(&resumed.trace.points) {
        assert_eq!(a.kappa, b.kappa);
        assert_eq!(a.rho_c, b.rho_c);
        assert_eq!(a.warm, b.warm);
        assert_eq!(a.iters, b.iters, "kappa {}: iteration counts differ", a.kappa);
        assert_eq!(a.converged, b.converged);
        assert_eq!(a.support, b.support, "kappa {}: supports differ", a.kappa);
        assert!(
            a.objective.to_bits() == b.objective.to_bits(),
            "kappa {}: objective bits differ ({} vs {})",
            a.kappa,
            a.objective,
            b.objective
        );
    }

    // a second resume finds everything done: no points are re-solved
    let done = run_path(&ds, &cfg, &opts(), false).unwrap();
    assert_eq!(done.resumed_points, 3);
    assert!(done.final_result.is_none());
    assert_eq!(done.trace.points.len(), 3);
    let _ = std::fs::remove_file(&ck);
}

/// A checkpoint written for different budgets (or any other trajectory-
/// shaping setting) must be rejected, not silently resumed.
#[test]
fn checkpoint_rejects_mismatched_problem() {
    let (spec, mut cfg) = planted(24, 2, 9);
    let ds = spec.generate();
    let k = spec.kappa();
    let ck = std::env::temp_dir().join("psfit_path_mismatch.psc");
    let _ = std::fs::remove_file(&ck);
    cfg.path.budgets = vec![2 * k, k];
    cfg.path.checkpoint = Some(ck.to_string_lossy().into_owned());
    cfg.path.limit = Some(1);
    run_path(&ds, &cfg, &opts(), false).unwrap();

    let mut other = cfg.clone();
    other.path.limit = None;
    other.path.budgets = vec![2 * k + 1, k];
    let err = run_path(&ds, &other, &opts(), false).unwrap_err().to_string();
    assert!(err.contains("different path run"), "{err}");
    let _ = std::fs::remove_file(&ck);
}

/// Reuse accounting: a warm sweep computes its Grams once and its rho
/// revisits hit the factorization cache; a cold sweep rebuilds per point.
#[test]
fn warm_sweep_reuses_grams_and_factorizations() {
    let (spec, mut cfg) = planted(24, 2, 11);
    let ds = spec.generate();
    let k = spec.kappa();
    cfg.path.budgets = vec![2 * k, k];
    // revisit rho 1.0 after 0.5: the third rung must reuse cached factors
    cfg.path.rho_ladder = vec![1.0, 0.5, 1.0];

    let warm = run_path(&ds, &cfg, &opts(), false).unwrap();
    assert_eq!(warm.trace.points.len(), 6);
    assert!(warm.trace.points[0].gram_builds > 0, "first point builds Grams");
    assert!(
        warm.trace.points[1..].iter().all(|p| p.gram_builds == 0),
        "a warm sweep never rebuilds a Gram: {:?}",
        warm.trace.points.iter().map(|p| p.gram_builds).collect::<Vec<_>>()
    );
    let reuses: u64 = warm.trace.points.iter().map(|p| p.chol_reuses).sum();
    assert!(reuses > 0, "the rho-ladder revisit must hit the cholesky cache");

    let mut cfg_cold = cfg.clone();
    cfg_cold.path.warm_start = false;
    let cold = run_path(&ds, &cfg_cold, &opts(), false).unwrap();
    assert!(
        cold.trace.points.iter().all(|p| p.gram_builds > 0),
        "a cold sweep rebuilds Grams at every point"
    );
    // across a rho ladder the warm trajectory may pay a little at each
    // rho switch, but the sweep as a whole must stay in the cold run's
    // ballpark (the pure budget-descent win is pinned by `psfit
    // pathbench` in CI, where no ladder is involved)
    let warm_iters = warm.trace.total_iters();
    let cold_iters = cold.trace.total_iters();
    assert!(
        warm_iters <= cold_iters + cold_iters / 4,
        "warm sweep took far more iterations ({warm_iters}) than cold ({cold_iters})"
    );
}

// ---------------------------------------------------------------------
// warm-state plumbing across the transports
// ---------------------------------------------------------------------

fn make_workers(nodes: usize, seed: u64) -> (Vec<NodeWorker>, usize) {
    let mut spec = SyntheticSpec::regression(12, 40 * nodes, nodes);
    spec.seed = seed;
    let ds = spec.generate();
    let plan = FeaturePlan::new(12, 2, 512);
    let params = BlockParams {
        rho_l: 2.0,
        rho_c: 1.0,
        reg: 1.0 / (nodes as f64 * 10.0) + 1.0,
    };
    let workers = ds
        .shards
        .iter()
        .enumerate()
        .map(|(i, shard)| {
            let be = NativeBackend::new(shard, &plan, Box::new(Squared), SolveMode::Direct);
            NodeWorker::new(
                i,
                psfit::admm::LocalProx::new(Box::new(be), plan.clone(), 1),
                params,
                6,
            )
        })
        .collect();
    (workers, 12)
}

/// Export from one cluster, re-seed a *fresh* cluster with it, and the
/// fresh cluster must continue the trajectory bit-for-bit — the property
/// the checkpoint format relies on.
#[test]
fn export_reseed_roundtrip_continues_bitwise() {
    let params = BlockParams {
        rho_l: 2.0,
        rho_c: 1.0,
        reg: 1.0 / (2.0 * 10.0) + 1.0,
    };
    let (w1, dim) = make_workers(2, 5);
    let mut original = SequentialCluster::new(w1, dim);
    let z = vec![0.05; dim];
    for _ in 0..3 {
        original.round(&z).unwrap();
    }
    let states = original.export_warm().unwrap();
    assert_eq!(states.len(), 2);
    assert_eq!(states[0].node, 0);

    let (w2, _) = make_workers(2, 5);
    let mut fresh = SequentialCluster::new(w2, dim);
    fresh.reseed(&states, params).unwrap();

    let a = original.round(&z).unwrap();
    let b = fresh.round(&z).unwrap();
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.node, rb.node);
        assert_eq!(ra.x, rb.x, "x must continue bit-for-bit");
        assert_eq!(ra.u, rb.u, "u must continue bit-for-bit");
    }
}

/// The threaded and async transports must answer export/reseed like the
/// sequential one (same states, usable for a continued round).
#[test]
fn threaded_and_async_transports_support_warm_state() {
    let params = BlockParams {
        rho_l: 2.0,
        rho_c: 1.0,
        reg: 1.0 / (2.0 * 10.0) + 1.0,
    };
    let z = vec![0.02; 12];

    let (w, dim) = make_workers(2, 6);
    let mut seq = SequentialCluster::new(w, dim);
    seq.round(&z).unwrap();
    let want = seq.export_warm().unwrap();

    let (w, _) = make_workers(2, 6);
    let mut thr = ThreadedCluster::new(w, dim);
    thr.round(&z).unwrap();
    let got = thr.export_warm().unwrap();
    assert_eq!(got, want, "threaded export must match sequential");
    thr.reseed(&got, params).unwrap();
    thr.round(&z).unwrap();

    let (w, _) = make_workers(2, 6);
    let cfg = psfit::config::CoordinatorConfig::default();
    let mut asy = AsyncCluster::new(w, dim, &cfg);
    asy.round(&z).unwrap();
    let got = asy.export_warm().unwrap();
    assert_eq!(got, want, "async export must match sequential");
    asy.reseed(&got, params).unwrap();
    asy.round(&z).unwrap();
}

//! Property-based tests of the coordinator invariants (psfit::util::testkit
//! drives seeded random cases; proptest itself is unavailable offline).
//!
//! Properties cover: the sparsity geometry (projections, s-update), data
//! partitioning (disjoint cover, scatter/gather, padding), the collectives
//! (threaded == sequential, allreduce == sum), and solver state rules
//! (dual updates, residual definitions, hard-threshold feasibility).

use psfit::data::partition::{shard_sizes, FeaturePlan};
use psfit::linalg::ops;
use psfit::linalg::Matrix;
use psfit::sparsity::{
    self, hard_threshold, project_l1_ball, project_l1_epigraph, support_f1, top_k_indices,
};
use psfit::util::rng::Rng;
use psfit::util::testkit::{assert_close, run_prop, PropConfig};

fn randvec(rng: &mut Rng, n: usize, scale: f64) -> Vec<f64> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

// ---------------------------------------------------------------- sparsity

#[test]
fn prop_l1_ball_projection_is_feasible_and_idempotent() {
    run_prop("l1_ball", PropConfig::default(), |rng, size| {
        let v = randvec(rng, size, 3.0);
        let r = rng.uniform() * 4.0;
        let w = project_l1_ball(&v, r);
        let l1: f64 = w.iter().map(|x| x.abs()).sum();
        if l1 > r + 1e-9 {
            return Err(format!("infeasible: {l1} > {r}"));
        }
        let w2 = project_l1_ball(&w, r);
        assert_close(&w, &w2, 1e-9)?;
        // projection never flips signs
        for (a, b) in v.iter().zip(&w) {
            if a * b < 0.0 {
                return Err("sign flip".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_l1_ball_projection_dominates_random_feasible_points() {
    run_prop("l1_ball_optimal", PropConfig { cases: 64, ..Default::default() }, |rng, size| {
        let v = randvec(rng, size, 2.0);
        let r = rng.uniform() * 2.0 + 0.1;
        let w = project_l1_ball(&v, r);
        let d_star = ops::dist2(&v, &w);
        for _ in 0..20 {
            // random feasible candidate: scaled random point on the ball
            let mut c = randvec(rng, size, 1.0);
            let l1: f64 = c.iter().map(|x| x.abs()).sum();
            if l1 > 0.0 {
                let scale = rng.uniform() * r / l1;
                for ci in c.iter_mut() {
                    *ci *= scale;
                }
            }
            if ops::dist2(&v, &c) < d_star - 1e-9 {
                return Err("found closer feasible point".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partial_selection_projections_match_sorted_oracle() {
    // the fast projections find their multiplier by select_nth-based
    // partial selection; the retired full-sort implementations remain as
    // the reference oracle
    run_prop(
        "projection_partial_selection",
        PropConfig::default(),
        |rng, size| {
            let mut v = randvec(rng, size, 3.0);
            if size >= 2 && rng.below(2) == 0 {
                // plant exact magnitude ties with mixed signs
                for i in (1..size).step_by(2) {
                    v[i] = -v[i - 1];
                }
            }
            let r = rng.uniform() * 4.0;
            let fast = project_l1_ball(&v, r);
            let oracle = sparsity::project_l1_ball_sorted(&v, r);
            assert_close(&fast, &oracle, 1e-9)?;
            let s = rng.normal() * 2.0;
            let (zf, tf) = project_l1_epigraph(&v, s);
            let (zo, to) = sparsity::project_l1_epigraph_sorted(&v, s);
            assert_close(&zf, &zo, 1e-9)?;
            if (tf - to).abs() > 1e-9 {
                return Err(format!("t mismatch: {tf} vs {to}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_epigraph_projection_feasible_idempotent_dominant() {
    run_prop("epigraph", PropConfig::default(), |rng, size| {
        let v = randvec(rng, size, 2.0);
        let s = rng.normal() * 2.0;
        let (z, t) = project_l1_epigraph(&v, s);
        let l1: f64 = z.iter().map(|x| x.abs()).sum();
        if l1 > t + 1e-8 {
            return Err(format!("infeasible: {l1} > {t}"));
        }
        let (z2, t2) = project_l1_epigraph(&z, t);
        assert_close(&z, &z2, 1e-8)?;
        if (t - t2).abs() > 1e-8 {
            return Err("t not idempotent".into());
        }
        // distance-dominance against soft-threshold candidates
        let d_star = ops::dist2(&v, &z) + (t - s) * (t - s);
        for k in 0..10 {
            let lam = k as f64 * 0.3;
            let zc: Vec<f64> = v
                .iter()
                .map(|&x| x.signum() * (x.abs() - lam).max(0.0))
                .collect();
            let tc: f64 = zc.iter().map(|x| x.abs()).sum();
            let d = ops::dist2(&v, &zc) + (tc - s) * (tc - s);
            if d < d_star - 1e-8 {
                return Err(format!("candidate beats projection: {d} < {d_star}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_projections_map_finite_inputs_to_finite_outputs() {
    // the numerical guardrails lean on this: any finite iterate — however
    // large — that reaches a projection comes back finite, so only the
    // reply guard and the watchdog have to reason about non-finite values
    run_prop("projection_finite", PropConfig::default(), |rng, size| {
        let scale = 10f64.powi(rng.below(101) as i32); // 1e0 ..= 1e100
        let v = randvec(rng, size, scale);
        let r = rng.uniform() * scale * 4.0;
        let w = project_l1_ball(&v, r);
        if w.iter().any(|x| !x.is_finite()) {
            return Err(format!("l1 ball output non-finite at scale {scale:e}"));
        }
        let s = rng.normal() * scale;
        let (z, t) = project_l1_epigraph(&v, s);
        if !t.is_finite() || z.iter().any(|x| !x.is_finite()) {
            return Err(format!("epigraph output non-finite at scale {scale:e}"));
        }
        Ok(())
    });
}

#[test]
fn prop_s_update_is_feasible_and_exact_when_reachable() {
    run_prop("s_update", PropConfig::default(), |rng, size| {
        let z = randvec(rng, size, 2.0);
        let kappa = 1 + rng.below(size);
        let tau = rng.normal() * 3.0;
        let s = sparsity::s_update(&z, tau, kappa);
        let l1: f64 = s.iter().map(|x| x.abs()).sum();
        let linf = s.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        if l1 > kappa as f64 + 1e-9 || linf > 1.0 + 1e-9 {
            return Err(format!("infeasible: l1={l1}, linf={linf}"));
        }
        let mut idx = top_k_indices(&z, kappa);
        idx.sort_unstable();
        let mx: f64 = idx.iter().map(|&i| z[i].abs()).sum();
        let zs = ops::dot(&z, &s);
        if tau.abs() <= mx {
            if (zs - tau).abs() > 1e-9 * (1.0 + tau.abs()) {
                return Err(format!("not exact: z^T s = {zs} vs tau = {tau}"));
            }
        } else if (zs - tau.signum() * mx).abs() > 1e-9 * (1.0 + mx) {
            return Err(format!("not saturated: {zs} vs {}", tau.signum() * mx));
        }
        Ok(())
    });
}

#[test]
fn prop_hard_threshold_keeps_largest() {
    run_prop("hard_threshold", PropConfig::default(), |rng, size| {
        let v = randvec(rng, size, 1.0);
        let k = rng.below(size + 1);
        let mut w = v.clone();
        let kept = hard_threshold(&mut w, k);
        if kept.len() != k.min(size) {
            return Err("wrong support size".into());
        }
        let min_kept = kept.iter().map(|&i| v[i].abs()).fold(f64::INFINITY, f64::min);
        for i in 0..size {
            if w[i] == 0.0 && v[i].abs() > min_kept + 1e-12 && !kept.contains(&i) {
                return Err(format!("dropped larger element at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_support_f1_bounds_and_symmetry() {
    run_prop("support_f1", PropConfig::default(), |rng, size| {
        let a: Vec<usize> = (0..size).filter(|_| rng.uniform() < 0.4).collect();
        let b: Vec<usize> = (0..size).filter(|_| rng.uniform() < 0.4).collect();
        let f = support_f1(&a, &b);
        if !(0.0..=1.0).contains(&f) {
            return Err(format!("f1 out of range: {f}"));
        }
        if (support_f1(&b, &a) - f).abs() > 1e-12 {
            return Err("not symmetric".into());
        }
        if support_f1(&a, &a) != 1.0 && !a.is_empty() {
            return Err("self f1 != 1".into());
        }
        Ok(())
    });
}

// ------------------------------------------------------------- partitioning

#[test]
fn prop_shard_sizes_cover_and_balance() {
    run_prop("shard_sizes", PropConfig::default(), |rng, size| {
        let nodes = 1 + rng.below(8);
        let m = size * 7 + rng.below(13);
        let sizes = shard_sizes(m, nodes);
        if sizes.iter().sum::<usize>() != m {
            return Err("does not cover".into());
        }
        let (mx, mn) = (
            *sizes.iter().max().unwrap(),
            *sizes.iter().min().unwrap(),
        );
        if mx - mn > 1 {
            return Err("unbalanced".into());
        }
        Ok(())
    });
}

#[test]
fn prop_feature_plan_disjoint_cover_and_roundtrip() {
    run_prop("feature_plan", PropConfig::default(), |rng, size| {
        let n = size + 1;
        let blocks = 1 + rng.below(6);
        let block_n = 1 + rng.below(2 * n);
        let plan = FeaturePlan::new(n, blocks, block_n.max(n.div_ceil(64)));
        let mut covered = vec![false; n];
        for &(s, w) in &plan.ranges {
            for i in s..s + w {
                if covered[i] {
                    return Err(format!("overlap at {i}"));
                }
                covered[i] = true;
            }
        }
        if !covered.iter().all(|&c| c) {
            return Err("not covering".into());
        }
        // scatter/gather round-trip
        let global = randvec(rng, n, 1.0);
        let mut rebuilt = vec![0.0; n];
        let mut buf = Vec::new();
        for b in 0..plan.blocks {
            plan.gather(b, &global, plan.padded_width.min(1 << 20), &mut buf);
            plan.scatter(b, &buf, &mut rebuilt);
        }
        assert_close(&global, &rebuilt, 0.0)?;
        Ok(())
    });
}

#[test]
fn prop_row_tile_padding_preserves_products() {
    run_prop("tile_padding", PropConfig { cases: 48, ..Default::default() }, |rng, size| {
        let m = size + 2;
        let n = 1 + rng.below(16);
        let mut a = Matrix::zeros(m, n);
        a.for_each_mut(|v| *v = rng.normal_f32());
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        // padded tile
        let tile_rows = m + rng.below(8) + 1;
        let mut buf = vec![f32::NAN; tile_rows * n];
        a.pack_row_tile(0, m, &mut buf);
        let padded = Matrix::from_flat(tile_rows, n, &buf);
        let mut y_pad = vec![0.0f32; tile_rows];
        padded.matvec(&x, &mut y_pad);
        let mut y = vec![0.0f32; m];
        a.matvec(&x, &mut y);
        for i in 0..m {
            if (y[i] - y_pad[i]).abs() > 1e-5 {
                return Err(format!("row {i}: {} vs {}", y[i], y_pad[i]));
            }
        }
        if y_pad[m..].iter().any(|&v| v != 0.0) {
            return Err("padding rows produced nonzero output".into());
        }
        Ok(())
    });
}

// ------------------------------------------------------------- collectives

#[test]
fn prop_threaded_cluster_equals_sequential() {
    use psfit::backend::native::{NativeBackend, SolveMode};
    use psfit::backend::BlockParams;
    use psfit::losses::Squared;
    use psfit::network::{Cluster, NodeWorker, SequentialCluster, ThreadedCluster};

    run_prop(
        "threaded_eq_sequential",
        PropConfig {
            cases: 12,
            max_size: 24,
            ..Default::default()
        },
        |rng, size| {
            let n = 4 + size;
            let nodes = 1 + rng.below(4);
            let mut spec = psfit::data::SyntheticSpec::regression(n, (8 + size) * nodes, nodes);
            spec.seed = rng.next_u64();
            let ds = spec.generate();
            let params = BlockParams {
                rho_l: 2.0,
                rho_c: 1.0,
                reg: 1.1,
            };
            let build = || -> Vec<NodeWorker> {
                ds.shards
                    .iter()
                    .enumerate()
                    .map(|(i, shard)| {
                        let plan = FeaturePlan::new(n, 2, 1 << 20);
                        let be =
                            NativeBackend::new(shard, &plan, Box::new(Squared), SolveMode::Direct);
                        NodeWorker::new(
                            i,
                            psfit::admm::LocalProx::new(Box::new(be), plan, 1),
                            params,
                            2,
                        )
                    })
                    .collect()
            };
            let mut seq = SequentialCluster::new(build(), n);
            let mut thr = ThreadedCluster::new(build(), n);
            let z = randvec(rng, n, 0.5);
            for _ in 0..2 {
                let a = seq.round(&z).map_err(|e| e.to_string())?;
                let b = thr.round(&z).map_err(|e| e.to_string())?;
                for (ra, rb) in a.iter().zip(&b) {
                    if ra.node != rb.node {
                        return Err("reply order".into());
                    }
                    assert_close(&ra.x, &rb.x, 1e-12)?;
                    assert_close(&ra.u, &rb.u, 1e-12)?;
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ solver rules

#[test]
fn prop_worker_dual_update_matches_consensus_rule() {
    use psfit::backend::native::{NativeBackend, SolveMode};
    use psfit::backend::BlockParams;
    use psfit::losses::Squared;
    use psfit::network::NodeWorker;

    run_prop(
        "dual_update",
        PropConfig {
            cases: 16,
            max_size: 16,
            ..Default::default()
        },
        |rng, size| {
            let n = 4 + size;
            let mut spec = psfit::data::SyntheticSpec::regression(n, 30 + size, 1);
            spec.seed = rng.next_u64();
            let ds = spec.generate();
            let plan = FeaturePlan::new(n, 1, 1 << 20);
            let be = NativeBackend::new(&ds.shards[0], &plan, Box::new(Squared), SolveMode::Direct);
            let params = BlockParams {
                rho_l: 2.0,
                rho_c: 1.0,
                reg: 1.1,
            };
            let mut w = NodeWorker::new(0, psfit::admm::LocalProx::new(Box::new(be), plan, 1), params, 2);
            let z0 = randvec(rng, n, 0.3);
            let (x1, u0) = w.round(&z0);
            if u0.iter().any(|&v| v != 0.0) {
                return Err("first-round dual nonzero".into());
            }
            let z1 = randvec(rng, n, 0.3);
            let (_x2, u1) = w.round(&z1);
            // u1 = u0 + x1 - z1
            let want: Vec<f64> = x1.iter().zip(&z1).map(|(x, z)| x - z).collect();
            assert_close(&u1, &want, 1e-12)?;
            Ok(())
        },
    );
}

#[test]
fn prop_residual_definitions_match_paper() {
    use psfit::admm::GlobalState;

    run_prop("residuals", PropConfig::default(), |rng, size| {
        let n = 2 + size;
        let nodes = 1 + rng.below(5);
        let mut g = GlobalState::new(n);
        g.z = randvec(rng, n, 1.0);
        let xs: Vec<Vec<f64>> = (0..nodes).map(|_| randvec(rng, n, 1.0)).collect();
        let rho_c = 0.5 + rng.uniform() * 3.0;
        let xs_refs: Vec<&[f64]> = xs.iter().map(|x| x.as_slice()).collect();
        let rec = g.residuals(xs_refs.iter().copied(), rho_c, 3, 0.0);
        // p_r = sum_i ||x_i - z||
        let want_p: f64 = xs.iter().map(|x| ops::dist2(x, &g.z).sqrt()).sum();
        if (rec.primal - want_p).abs() > 1e-12 * (1.0 + want_p) {
            return Err("primal residual mismatch".into());
        }
        // d_r with z_prev = 0: sqrt(N) rho_c ||z||
        let want_d = (nodes as f64).sqrt() * rho_c * ops::norm2(&g.z);
        if (rec.dual - want_d).abs() > 1e-12 * (1.0 + want_d) {
            return Err("dual residual mismatch".into());
        }
        Ok(())
    });
}

//! SIMD kernel backend invariants.
//!
//! * Forced-ISA parity: every supported variant (scalar tiled, AVX2,
//!   NEON) agrees with the `_naive` reference twins — and with every
//!   other variant — within the crate-wide 1e-5 contract, across
//!   unaligned tails, non-multiple-of-lane widths, empty and strided
//!   blocks, and dense-vs-CSR storage.
//! * Dispatch plumbing: the `platform.isa` / `PSFIT_ISA` override knob
//!   (`simd::select`) actually selects the named path, rejects variants
//!   the host lacks, and `auto` restores the baseline.
//! * End-to-end: whole solves under each ISA recover the identical
//!   support and objectives within the same contract.
//!
//! Tests that flip the process-global ISA override serialize on a local
//! mutex and restore the previous selection; everything else pins
//! variants through the side-effect-free `*_isa` entry points.

use std::sync::Mutex;

use psfit::config::Config;
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::linalg::csr::{self, CsrMatrix};
use psfit::linalg::kernels;
use psfit::linalg::simd::{self, Isa, IsaChoice};
use psfit::linalg::Matrix;
use psfit::util::rng::Rng;
use psfit::util::testkit::{assert_close_f32, run_prop, PropConfig};

/// Serializes the tests that mutate the process-global ISA override.
static ISA_LOCK: Mutex<()> = Mutex::new(());

/// Restores the pre-test ISA selection on drop (panic-safe).
struct IsaGuard(Isa);

impl IsaGuard {
    fn hold() -> IsaGuard {
        IsaGuard(simd::active())
    }
}

impl Drop for IsaGuard {
    fn drop(&mut self) {
        let _ = simd::select(IsaChoice::Force(self.0));
    }
}

fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    m.for_each_mut(|v| *v = rng.normal_f32());
    m
}

fn randvec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal_f32(&mut v);
    v
}

/// Shapes that deliberately straddle every lane width in play (4-wide
/// scalar unroll, 4-wide NEON, 8/32-wide AVX2): zero rows included.
fn rand_shape(rng: &mut Rng, size: usize) -> (usize, usize) {
    let rows = rng.below(2 * size + 5); // 0 included
    let cols = 1 + rng.below(size + 37); // crosses the 8- and 32-lane edges
    (rows, cols)
}

#[test]
fn prop_forced_isa_dense_kernels_match_naive() {
    run_prop("simd_dense_parity", PropConfig::default(), |rng, size| {
        let (rows, cols) = rand_shape(rng, size);
        let a = randmat(rng, rows, cols);
        // random strided sub-block, so SIMD rows start at unaligned
        // offsets of the parent stride
        let w = 1 + rng.below(cols);
        let col0 = rng.below(cols - w + 1);
        let view = a.column_block_view(col0, w);
        let k = 1 + rng.below(3);

        let x = randvec(rng, w);
        let v = randvec(rng, rows);
        let xk = randvec(rng, k * w);
        let vk = randvec(rng, k * rows);

        let mut y_ref = vec![0.0f32; rows];
        kernels::matvec_naive(&view, &x, &mut y_ref);
        let mut yt_ref = vec![0.0f32; w];
        kernels::matvec_t_naive(&view, &v, &mut yt_ref);
        let mut g_ref = vec![0.0f32; w * w];
        kernels::gram_naive(&view, &mut g_ref);
        let mut yk_ref = vec![0.0f32; k * rows];
        kernels::matmul_naive(&view, &xk, k, &mut yk_ref);
        let mut vk_ref = vec![0.0f32; k * w];
        kernels::matmul_t_naive(&view, &vk, k, &mut vk_ref);

        for isa in simd::supported() {
            let mut y = vec![0.0f32; rows];
            kernels::matvec_isa(isa, &view, &x, &mut y);
            assert_close_f32(&y_ref, &y, 1e-5).map_err(|e| format!("{} matvec: {e}", isa.name()))?;
            let mut yt = vec![0.0f32; w];
            kernels::matvec_t_isa(isa, &view, &v, &mut yt);
            assert_close_f32(&yt_ref, &yt, 1e-5)
                .map_err(|e| format!("{} matvec_t: {e}", isa.name()))?;
            let mut g = vec![0.0f32; w * w];
            kernels::gram_isa(isa, &view, &mut g);
            assert_close_f32(&g_ref, &g, 1e-5).map_err(|e| format!("{} gram: {e}", isa.name()))?;
            let mut yk = vec![0.0f32; k * rows];
            kernels::matmul_isa(isa, &view, &xk, k, &mut yk);
            assert_close_f32(&yk_ref, &yk, 1e-5)
                .map_err(|e| format!("{} matmul: {e}", isa.name()))?;
            let mut vk_out = vec![0.0f32; k * w];
            kernels::matmul_t_isa(isa, &view, &vk, k, &mut vk_out);
            assert_close_f32(&vk_ref, &vk_out, 1e-5)
                .map_err(|e| format!("{} matmul_t: {e}", isa.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_forced_isa_csr_kernels_match_dense_scalar() {
    run_prop(
        "simd_csr_parity",
        PropConfig {
            cases: 96,
            max_size: 32,
            ..Default::default()
        },
        |rng, size| {
            let (rows, cols) = rand_shape(rng, size);
            let density = [0.0, 0.05, 0.3, 1.0][rng.below(4)];
            let mut a = randmat(rng, rows, cols);
            a.for_each_mut(|v| {
                if rng.uniform() >= density {
                    *v = 0.0;
                }
            });
            let c = CsrMatrix::from_dense(&a);
            let w = 1 + rng.below(cols);
            let col0 = rng.below(cols - w + 1);
            let ranges = c.block_ranges(col0, w);
            let sv = c.block_view(&ranges, col0, w);
            let dv = a.column_block_view(col0, w);
            let k = 1 + rng.below(3);

            let x = randvec(rng, k * w);
            let v = randvec(rng, k * rows);
            let mut y_ref = vec![0.0f32; k * rows];
            kernels::matmul_naive(&dv, &x, k, &mut y_ref);
            let mut z_ref = vec![0.0f32; k * w];
            kernels::matmul_t_naive(&dv, &v, k, &mut z_ref);

            for isa in simd::supported() {
                let mut y = vec![0.0f32; k * rows];
                csr::spmm_isa(isa, &sv, &x, k, &mut y);
                assert_close_f32(&y_ref, &y, 1e-5)
                    .map_err(|e| format!("{} spmm: {e}", isa.name()))?;
                let mut z = vec![0.0f32; k * w];
                csr::spmm_t_isa(isa, &sv, &v, k, &mut z);
                assert_close_f32(&z_ref, &z, 1e-5)
                    .map_err(|e| format!("{} spmm_t: {e}", isa.name()))?;
            }
            Ok(())
        },
    );
}

/// The override knob must actually select the named path.
#[test]
fn dispatch_override_selects_named_path() {
    let _lock = ISA_LOCK.lock().unwrap();
    let _guard = IsaGuard::hold();

    // forcing scalar always works, on any host
    assert_eq!(simd::select(IsaChoice::Force(Isa::Scalar)).unwrap(), Isa::Scalar);
    assert_eq!(simd::active(), Isa::Scalar);

    // every supported variant is selectable and becomes the active path
    for isa in simd::supported() {
        assert_eq!(simd::select(IsaChoice::Force(isa)).unwrap(), isa);
        assert_eq!(simd::active(), isa);
    }

    // an unavailable variant is rejected and leaves the selection alone
    let before = simd::active();
    for isa in [Isa::Avx2, Isa::Neon] {
        if !simd::available(isa) {
            assert!(simd::select(IsaChoice::Force(isa)).is_err());
            assert_eq!(simd::active(), before);
        }
    }

    // auto clears the override: active falls back to the env/auto
    // baseline, which is always one of the supported variants
    let auto = simd::select(IsaChoice::Auto).unwrap();
    assert_eq!(simd::active(), auto);
    assert!(simd::supported().contains(&auto));
}

/// Whole solves under every ISA must recover the identical support and
/// agree on the objective within the kernel contract.
#[test]
fn solver_support_and_objective_identical_across_isas() {
    let _lock = ISA_LOCK.lock().unwrap();
    let _guard = IsaGuard::hold();

    let mut spec = SyntheticSpec::regression(40, 400, 2);
    spec.sparsity_level = 0.8;
    spec.noise_std = 0.02;
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 300;

    let loss = psfit::losses::make_loss(cfg.loss, ds.width);
    let mut results = Vec::new();
    for isa in simd::supported() {
        simd::select(IsaChoice::Force(isa)).unwrap();
        let res = driver::fit(&ds, &cfg).unwrap();
        let obj = psfit::admm::solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &res.x);
        results.push((isa, res, obj));
    }
    let (_, ref_res, ref_obj) = &results[0];
    for (isa, res, obj) in &results[1..] {
        assert_eq!(
            &ref_res.support, &res.support,
            "support differs under {}",
            isa.name()
        );
        let scale = ref_obj.abs().max(1.0);
        assert!(
            (ref_obj - obj).abs() <= 1e-5 * scale,
            "objective under {}: {obj} vs {ref_obj}",
            isa.name()
        );
    }
}

/// Forcing the scalar ISA reproduces the historical tiled kernels
/// bit-for-bit end to end (the "guaranteed fallback" contract): two
/// scalar solves of the same problem are bit-identical, and a CSR-stored
/// solve matches the dense one to kernel tolerance under every ISA.
#[test]
fn scalar_fallback_is_deterministic_and_csr_agrees() {
    let _lock = ISA_LOCK.lock().unwrap();
    let _guard = IsaGuard::hold();

    let mut spec = SyntheticSpec::regression(30, 300, 2);
    spec.density = 0.15;
    spec.noise_std = 0.02;
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 40;

    simd::select(IsaChoice::Force(Isa::Scalar)).unwrap();
    let a = driver::fit(&ds, &cfg).unwrap();
    let b = driver::fit(&ds, &cfg).unwrap();
    assert_eq!(a.z, b.z, "scalar path must be bit-deterministic");
    assert_eq!(a.x, b.x);

    // converged dense-vs-CSR runs agree on the support under every ISA
    cfg.solver.max_iters = 300;
    for isa in simd::supported() {
        simd::select(IsaChoice::Force(isa)).unwrap();
        let mut dense_cfg = cfg.clone();
        dense_cfg.platform.sparse = psfit::data::SparseMode::Never;
        let mut csr_cfg = cfg.clone();
        csr_cfg.platform.sparse = psfit::data::SparseMode::Always;
        let dense = driver::fit(&ds, &dense_cfg).unwrap();
        let sparse = driver::fit(&ds, &csr_cfg).unwrap();
        assert_eq!(
            dense.support,
            sparse.support,
            "{}: dense vs csr support",
            isa.name()
        );
    }
}

//! Socket-transport integration tests: wire-codec roundtrips and
//! rejection paths, localhost bit-parity against the in-process
//! transports, peer-loss degradation, exact wire-byte accounting, the
//! `psfit serve` daemon, and one real `psfit worker` subprocess.

use std::io::Write;
use std::time::Duration;

use psfit::admm::SolveOptions;
use psfit::config::{Config, TransportKind};
use psfit::data::SyntheticSpec;
use psfit::driver;
use psfit::losses::make_loss;
use psfit::metrics::TransferLedger;
use psfit::network::socket::wire::{
    self, JobSpec, JobStatus, JobSummary, Setup, WireCommand, WireShard, WireShardData,
    FRAME_OVERHEAD,
};
use psfit::network::socket::worker::spawn_flaky_worker;
use psfit::network::socket::{
    connect, spawn_local_worker, Endpoint, SocketCluster, SocketListener,
};
use psfit::network::{Cluster, WarmState};
use psfit::serve::{spawn_local_serve, FittedModel, ServeClient};
use psfit::util::rng::Rng;
use psfit::util::testkit::{run_prop, PropConfig};

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// ------------------------------------------------------------- wire codec

fn rand_f64s(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

fn rand_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn rand_name(rng: &mut Rng) -> String {
    (0..rng.below(12))
        .map(|_| char::from(b'a' + rng.below(26) as u8))
        .collect()
}

fn rand_warm(rng: &mut Rng, size: usize) -> WarmState {
    WarmState {
        node: rng.below(8),
        x: rand_f64s(rng, size),
        u: rand_f64s(rng, size),
        omega: rand_f32s(rng, rng.below(size + 1)),
        nu: rand_f32s(rng, rng.below(size + 1)),
        preds: (0..rng.below(3))
            .map(|_| rand_f32s(rng, rng.below(size + 1)))
            .collect(),
    }
}

fn rand_shard(rng: &mut Rng, size: usize) -> WireShard {
    let rows = 1 + rng.below(4);
    let cols = 1 + rng.below(size.max(1));
    let data = if rng.below(2) == 0 {
        WireShardData::Dense {
            rows: rows as u32,
            cols: cols as u32,
            vals: rand_f32s(rng, rows * cols),
        }
    } else {
        let lists = (0..rows)
            .map(|_| {
                let mut idx = rng.choose_indices(cols, rng.below(cols + 1));
                idx.sort_unstable();
                idx.into_iter()
                    .map(|j| (j as u32, rng.normal_f32()))
                    .collect()
            })
            .collect();
        WireShardData::Csr {
            cols: cols as u32,
            rows: lists,
        }
    };
    WireShard {
        labels: rand_f32s(rng, rows),
        data,
    }
}

fn rand_ledger(rng: &mut Rng) -> TransferLedger {
    let mut l = TransferLedger::default();
    l.h2d_bytes = rng.next_u64() >> 32;
    l.d2h_bytes = rng.next_u64() >> 32;
    l.copy_seconds = rng.uniform();
    l.net_up_bytes = rng.next_u64() >> 32;
    l.net_down_bytes = rng.next_u64() >> 32;
    l.net_resync_bytes = rng.next_u64() >> 32;
    l.host_copy_saved_bytes = rng.next_u64() >> 32;
    l.net_alloc_saved_bytes = rng.next_u64() >> 32;
    l.gram_builds = rng.next_u64() >> 48;
    l.chol_factorizations = rng.next_u64() >> 48;
    l.chol_reuses = rng.next_u64() >> 48;
    l.wire_frames = rng.next_u64() >> 48;
    l
}

fn rand_status(rng: &mut Rng) -> JobStatus {
    JobStatus {
        job: rng.next_u64(),
        phase: rng.below(4) as u8,
        converged: rng.below(2) == 0,
        iters: rng.next_u64() >> 48,
        support_len: rng.next_u64() >> 48,
        objective: rng.normal(),
        wall_seconds: rng.uniform(),
        message: rand_name(rng),
    }
}

fn rand_command(rng: &mut Rng, size: usize) -> WireCommand {
    match rng.below(22) {
        0 => WireCommand::Setup(Box::new(Setup {
            node: rng.below(8) as u32,
            nodes: 1 + rng.below(8) as u32,
            n_features: 1 + rng.below(64) as u32,
            width: 1 + rng.below(3) as u32,
            direct_mode: rng.below(2) == 0,
            config: rand_name(rng),
            shard: rand_shard(rng, size),
        })),
        1 => WireCommand::Round {
            round: rng.next_u64(),
            z: rand_f64s(rng, size),
        },
        2 => WireCommand::Loss,
        3 => WireCommand::Ledger,
        4 => WireCommand::Export,
        5 => WireCommand::Reseed {
            rho_l: rng.normal(),
            rho_c: rng.normal(),
            reg: rng.normal(),
            states: (0..1 + rng.below(3)).map(|_| rand_warm(rng, size)).collect(),
        },
        6 => WireCommand::Shutdown,
        7 => WireCommand::SetupOk {
            node: rng.below(8) as u32,
        },
        8 => WireCommand::RoundReply {
            node: rng.below(8) as u32,
            round: rng.next_u64(),
            x: rand_f64s(rng, size),
            u: rand_f64s(rng, size),
        },
        9 => WireCommand::LossReply { value: rng.normal() },
        10 => WireCommand::LedgerReply(Box::new(rand_ledger(rng))),
        11 => WireCommand::WarmReply(Box::new(rand_warm(rng, size))),
        12 => WireCommand::ReseedOk {
            node: rng.below(8) as u32,
        },
        13 => WireCommand::Error {
            message: rand_name(rng),
        },
        14 => WireCommand::Submit {
            name: rand_name(rng),
            spec: JobSpec {
                n: 1 + rng.below(256) as u32,
                m: 1 + rng.below(2048) as u32,
                nodes: 1 + rng.below(8) as u32,
                sparsity: rng.uniform(),
                density: rng.uniform().max(0.01),
                noise_std: rng.uniform(),
                seed: rng.next_u64(),
                kappa: rng.below(64) as u32,
                config: rand_name(rng),
            },
        },
        15 => WireCommand::Status { job: rng.next_u64() },
        16 => WireCommand::Predict {
            job: rng.next_u64(),
            features: (0..rng.below(6))
                .map(|_| (rng.below(64) as u32, rng.normal()))
                .collect(),
        },
        17 => WireCommand::Jobs,
        18 => WireCommand::Submitted { job: rng.next_u64() },
        19 => WireCommand::StatusReply(Box::new(rand_status(rng))),
        20 => WireCommand::PredictReply {
            values: rand_f64s(rng, rng.below(4)),
        },
        _ => WireCommand::JobsReply {
            jobs: (0..rng.below(4))
                .map(|_| JobSummary {
                    job: rng.next_u64(),
                    phase: rng.below(4) as u8,
                    name: rand_name(rng),
                })
                .collect(),
        },
    }
}

#[test]
fn prop_every_wire_command_roundtrips() {
    let cfg = PropConfig {
        cases: 96,
        max_size: 24,
        ..Default::default()
    };
    run_prop("wire_roundtrip", cfg, |rng, size| {
        let cmd = rand_command(rng, size);
        let mut buf = Vec::new();
        let n = wire::write_frame(&mut buf, &cmd).map_err(|e| e.to_string())?;
        if n != buf.len() {
            return Err(format!("reported {n} bytes, wrote {}", buf.len()));
        }
        let mut r = &buf[..];
        let (back, m) = wire::read_frame(&mut r)
            .map_err(|e| e.to_string())?
            .ok_or("missing frame")?;
        if m != n {
            return Err(format!("read reported {m} bytes, frame was {n}"));
        }
        if back != cmd {
            return Err(format!("`{}` did not roundtrip", cmd.name()));
        }
        Ok(())
    });
}

#[test]
fn truncated_and_corrupted_frames_are_rejected() {
    let cmd = WireCommand::Round {
        round: 7,
        z: vec![1.0, -2.0, 3.5],
    };
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, &cmd).unwrap();
    // clean EOF at a frame boundary is a `None`, not an error
    assert!(wire::read_frame(&mut &buf[..0]).unwrap().is_none());
    // every strict prefix is an error — truncation is never silent
    for cut in 1..buf.len() {
        assert!(wire::read_frame(&mut &buf[..cut]).is_err(), "prefix {cut}");
    }
    // flip one payload byte: the checksum catches it
    let mut bad = buf.clone();
    bad[6] ^= 0x40;
    let err = wire::read_frame(&mut &bad[..]).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");
    // corrupt the length header: rejected as a bad length or a short read
    let mut bad_len = buf.clone();
    bad_len[1] ^= 0xff;
    assert!(wire::read_frame(&mut &bad_len[..]).is_err());
}

#[test]
fn version_mismatch_handshake_is_rejected() {
    let listener = SocketListener::bind(&Endpoint::parse("127.0.0.1:0")).unwrap();
    let addr = listener.local_endpoint();
    let server = std::thread::spawn(move || {
        let mut s = listener.accept().unwrap();
        wire::server_handshake(&mut s).unwrap_err().to_string()
    });
    let mut c = connect(&Endpoint::parse(&addr), Duration::from_secs(2), 1).unwrap();
    let mut bad = [0u8; 8];
    bad[..4].copy_from_slice(b"PSFW");
    bad[4..].copy_from_slice(&99u32.to_le_bytes());
    c.write_all(&bad).unwrap();
    c.flush().unwrap();
    let err = server.join().unwrap();
    assert!(err.contains("version mismatch"), "{err}");
}

// --------------------------------------------------- cluster parity + faults

#[test]
fn socket_cluster_matches_sequential_bit_for_bit() {
    let spec = SyntheticSpec::regression(48, 240, 3);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 3;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 30;
    let base = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();

    let mut scfg = cfg.clone();
    scfg.platform.transport = TransportKind::Socket;
    scfg.platform.workers = (0..3)
        .map(|_| spawn_local_worker().unwrap())
        .collect();
    let sock = driver::fit_with_options(&ds, &scfg, &SolveOptions::default(), false).unwrap();

    assert_eq!(base.iters, sock.iters);
    assert_eq!(base.support, sock.support);
    assert_eq!(bits(&base.x), bits(&sock.x));
    assert_eq!(bits(&base.z), bits(&sock.z));
    let stats = sock.coordination.expect("socket cluster reports stats");
    assert_eq!(stats.deaths, 0);
}

#[test]
fn losing_a_worker_mid_run_degrades_to_the_survivors() {
    let spec = SyntheticSpec::regression(32, 180, 3);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 3;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 8;
    cfg.solver.tol_primal = 0.0; // fixed rounds: the death must land mid-run
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.workers = vec![
        spawn_local_worker().unwrap(),
        spawn_local_worker().unwrap(),
        spawn_flaky_worker(2).unwrap(),
    ];
    let res = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();
    assert_eq!(res.iters, 8, "quorum path keeps iterating after the death");
    let stats = res.coordination.expect("socket cluster reports stats");
    assert_eq!(stats.deaths, 1);
    let last = res.trace.records.last().unwrap();
    assert_eq!(last.participants, 2, "final rounds fold the two survivors");
}

#[test]
fn round_frames_are_ledgered_byte_for_byte() {
    let spec = SyntheticSpec::regression(16, 90, 2);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.workers = vec![spawn_local_worker().unwrap(), spawn_local_worker().unwrap()];
    let mut cluster = SocketCluster::connect(&ds, &cfg).unwrap();
    let dim = ds.n_features * ds.width;
    let z = vec![0.25; dim];
    let rounds = 3usize;
    for _ in 0..rounds {
        let replies = cluster.round(&z).unwrap();
        assert_eq!(replies.len(), 2);
    }
    let led = cluster.ledger();
    // Round frame:      tag + round counter + z           (+ frame overhead)
    // RoundReply frame: tag + node + round + x + u        (+ frame overhead)
    let down_frame = FRAME_OVERHEAD + 1 + 8 + (4 + dim * 8);
    let up_frame = FRAME_OVERHEAD + 1 + 4 + 8 + 2 * (4 + dim * 8);
    assert_eq!(led.net_down_bytes, (rounds * 2 * down_frame) as u64);
    assert_eq!(led.net_up_bytes, (rounds * 2 * up_frame) as u64);
    assert!(led.net_resync_bytes > 0, "handshake + setup are ledgered");
    assert!(led.wire_frames >= (rounds * 4) as u64);
}

// ------------------------------------------------------------- psfit serve

#[test]
fn serve_runs_concurrent_jobs_and_serves_bitexact_predictions() {
    let addr = spawn_local_serve(2).unwrap();
    let mut client = ServeClient::connect(&addr).unwrap();
    let spec_a = JobSpec {
        n: 48,
        m: 240,
        nodes: 2,
        ..JobSpec::default()
    };
    let spec_b = JobSpec {
        n: 32,
        m: 200,
        nodes: 2,
        seed: 7,
        ..JobSpec::default()
    };
    // submit both before waiting on either: the two fits run concurrently
    // over the same two-worker fleet
    let a = client.submit("alpha", spec_a.clone()).unwrap();
    let b = client.submit("beta", spec_b).unwrap();
    let sa = client.wait(a, Duration::from_secs(120)).unwrap();
    let sb = client.wait(b, Duration::from_secs(120)).unwrap();
    assert!(sa.support_len > 0 && sb.support_len > 0);

    let jobs = client.jobs().unwrap();
    assert_eq!(jobs.len(), 2);
    assert_eq!((jobs[0].job, jobs[0].name.as_str()), (a, "alpha"));
    assert_eq!((jobs[1].job, jobs[1].name.as_str()), (b, "beta"));

    // replicate job A locally (same synthetic recipe, default config) and
    // hold the daemon's predictions to bit-exactness
    let mut sspec = SyntheticSpec::regression(spec_a.n as usize, spec_a.m as usize, 2);
    sspec.sparsity_level = spec_a.sparsity;
    sspec.density = spec_a.density;
    sspec.noise_std = spec_a.noise_std;
    sspec.seed = spec_a.seed;
    let ds = sspec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = sspec.kappa();
    let res = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();
    let loss = make_loss(cfg.loss, ds.width.max(cfg.classes));
    let objective = psfit::admm::solver::objective(&ds, loss.as_ref(), cfg.solver.gamma, &res.x);
    let model = FittedModel::from_solution(
        ds.n_features,
        ds.width,
        res.support.clone(),
        &res.x,
        objective,
    );
    assert_eq!(sa.objective.to_bits(), objective.to_bits());

    let query = vec![(0u32, 1.0), (5, -2.0), (17, 0.5)];
    let remote = client.predict(a, &query).unwrap();
    let local = model.predict_sparse(&query);
    assert_eq!(bits(&remote), bits(&local));

    // unknown jobs error without poisoning the session
    let err = client.predict(999, &query).unwrap_err().to_string();
    assert!(err.contains("no fitted model"), "{err}");
    let err = client.status(999).unwrap_err().to_string();
    assert!(err.contains("no such job"), "{err}");
    assert_eq!(client.jobs().unwrap().len(), 2, "session survives the error");
}

// ------------------------------------------------------- worker subprocess

#[test]
fn a_real_worker_process_serves_a_single_node_fit() {
    use std::io::BufRead;
    use std::process::{Child, Command, Stdio};

    struct Guard(Child);
    impl Drop for Guard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    let mut child = Command::new(env!("CARGO_BIN_EXE_psfit"))
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn psfit worker");
    let stdout = child.stdout.take().unwrap();
    let guard = Guard(child);
    let mut line = String::new();
    std::io::BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .strip_prefix("psfit worker listening on ")
        .unwrap_or_else(|| panic!("unexpected worker banner: {line:?}"))
        .to_string();

    let spec = SyntheticSpec::regression(24, 120, 1);
    let ds = spec.generate();
    let mut cfg = Config::default();
    cfg.platform.nodes = 1;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 20;
    let base = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();
    cfg.platform.transport = TransportKind::Socket;
    cfg.platform.workers = vec![addr];
    let sock = driver::fit_with_options(&ds, &cfg, &SolveOptions::default(), false).unwrap();
    assert_eq!(base.support, sock.support);
    assert_eq!(bits(&base.x), bits(&sock.x));
    drop(guard);
}

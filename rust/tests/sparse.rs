//! Sparse data path: CSR kernels pinned against the dense layer across
//! random densities (all-zero rows/columns, empty blocks, and
//! non-multiple-of-4 shapes included), and end-to-end solver parity —
//! `--sparse always` and `--sparse never` must recover identical supports
//! and matching objectives on a synthetic sparse dataset.

use psfit::admm::solver::objective;
use psfit::config::Config;
use psfit::data::{SparseMode, SyntheticSpec};
use psfit::driver;
use psfit::linalg::{csr, kernels, CsrMatrix, Matrix};
use psfit::losses::Squared;
use psfit::util::rng::Rng;
use psfit::util::testkit::{assert_close_f32, run_prop, PropConfig};

/// Random dense matrix with ~`density` nonzero fraction; `density` 0.0
/// yields the all-zero matrix (every row and column empty).
fn rand_sparse(rng: &mut Rng, m: usize, n: usize, density: f64) -> Matrix {
    let mut a = Matrix::zeros(m, n);
    a.for_each_mut(|v| {
        if rng.uniform() < density {
            *v = rng.normal_f32();
        }
    });
    a
}

#[test]
fn prop_csr_kernels_match_dense_kernels() {
    run_prop(
        "csr_vs_dense",
        PropConfig {
            cases: 96,
            max_size: 24,
            ..Default::default()
        },
        |rng, size| {
            // deliberately not multiples of 4; size 1 gives 1x1
            let m = 1 + size;
            let n = 1 + (size * 7) % 19;
            // sweep all-zero through dense, with zero-heavy emphasis
            let density = match rng.below(4) {
                0 => 0.0,
                1 => 0.05,
                2 => rng.uniform(),
                _ => 1.0,
            };
            let a = rand_sparse(rng, m, n, density);
            let c = CsrMatrix::from_dense(&a);
            if c.to_dense() != a {
                return Err("from_dense/to_dense roundtrip drifted".into());
            }

            // random column block, including width-0 neighborhood edges
            let col0 = rng.below(n);
            let w = 1 + rng.below(n - col0);
            let ranges = c.block_ranges(col0, w);
            let sv = c.block_view(&ranges, col0, w);
            let dv = a.column_block_view(col0, w);

            let k = 1 + rng.below(3);
            let x: Vec<f32> = (0..k * w).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..k * m).map(|_| rng.normal_f32()).collect();

            let (mut y0, mut y1) = (vec![0.0f32; k * m], vec![0.0f32; k * m]);
            kernels::matmul(&dv, &x, k, &mut y0);
            csr::spmm(&sv, &x, k, &mut y1);
            assert_close_f32(&y0, &y1, 1e-5)?;
            csr::spmm_naive(&sv, &x, k, &mut y1);
            assert_close_f32(&y0, &y1, 1e-5)?;

            let (mut z0, mut z1) = (vec![0.0f32; k * w], vec![0.0f32; k * w]);
            kernels::matmul_t(&dv, &v, k, &mut z0);
            csr::spmm_t(&sv, &v, k, &mut z1);
            assert_close_f32(&z0, &z1, 1e-5)?;
            csr::spmm_t_naive(&sv, &v, k, &mut z1);
            assert_close_f32(&z0, &z1, 1e-5)?;

            let (mut g0, mut g1) = (vec![0.0f32; w * w], vec![0.0f32; w * w]);
            kernels::gram(&dv, &mut g0);
            csr::gram_sparse(&sv, &mut g1);
            assert_close_f32(&g0, &g1, 1e-5)?;
            g1.fill(0.0);
            csr::gram_sparse_naive(&sv, &mut g1);
            assert_close_f32(&g0, &g1, 1e-5)?;

            // single-vector twins agree with the multi-RHS k = 1 case
            let (mut s0, mut s1) = (vec![0.0f32; m], vec![0.0f32; m]);
            csr::spmv(&sv, &x[..w], &mut s0);
            csr::spmv_naive(&sv, &x[..w], &mut s1);
            assert_close_f32(&s0, &s1, 1e-5)?;
            let (mut t0, mut t1) = (vec![0.0f32; w], vec![0.0f32; w]);
            csr::spmv_t(&sv, &v[..m], &mut t0);
            csr::spmv_t_naive(&sv, &v[..m], &mut t1);
            assert_close_f32(&t0, &t1, 1e-5)?;
            Ok(())
        },
    );
}

/// The acceptance gate: forcing CSR and forcing dense storage must walk
/// the solver to the same answer on a genuinely sparse planted problem —
/// identical supports, matching objectives.
#[test]
fn sparse_always_and_never_recover_identical_supports() {
    let mut spec = SyntheticSpec::regression(60, 480, 2);
    spec.sparsity_level = 0.8; // kappa = 12
    spec.density = 0.05;
    spec.noise_std = 0.02;
    let ds = spec.generate();

    let mut results = Vec::new();
    for mode in [SparseMode::Always, SparseMode::Never] {
        let mut cfg = Config::default();
        cfg.platform.nodes = 2;
        cfg.solver.kappa = spec.kappa();
        cfg.solver.max_iters = 300;
        cfg.platform.sparse = mode;
        let res = driver::fit(&ds, &cfg).unwrap();
        let obj = objective(&ds, &Squared, cfg.solver.gamma, &res.x);
        results.push((res, obj));
    }
    let (csr_res, csr_obj) = &results[0];
    let (dense_res, dense_obj) = &results[1];
    assert_eq!(
        csr_res.support, dense_res.support,
        "storage format changed the recovered support"
    );
    assert_eq!(csr_res.support.len(), spec.kappa());
    let scale = dense_obj.abs().max(1.0);
    assert!(
        (csr_obj - dense_obj).abs() <= 1e-5 * scale,
        "objectives diverged: {csr_obj} vs {dense_obj}"
    );
}

/// `Auto` with the default 0.25 threshold must route a density-0.05
/// dataset to CSR and a dense dataset to dense storage, and both runs
/// must still converge to the planted support.
#[test]
fn auto_policy_routes_by_density_and_still_recovers() {
    let mut spec = SyntheticSpec::regression(40, 400, 2);
    spec.sparsity_level = 0.8;
    spec.density = 0.05;
    spec.noise_std = 0.02;
    let ds = spec.generate();
    assert!(ds.density() < 0.25, "planted dataset should be sparse");
    let shard = ds.shards[0].with_storage_policy(SparseMode::Auto, 0.25);
    assert_eq!(shard.data.storage_name(), "csr");

    let mut cfg = Config::default();
    cfg.platform.nodes = 2;
    cfg.solver.kappa = spec.kappa();
    cfg.solver.max_iters = 300;
    let res = driver::fit(&ds, &cfg).unwrap();
    let f1 = psfit::sparsity::support_f1(&res.support, &ds.support_true);
    assert!(f1 > 0.9, "support F1 = {f1} on the CSR auto path");

    // dense data stays dense under auto
    let dense_ds = SyntheticSpec::regression(20, 80, 1).generate();
    let shard = dense_ds.shards[0].with_storage_policy(SparseMode::Auto, 0.25);
    assert_eq!(shard.data.storage_name(), "dense");
}

//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so this crate
//! implements exactly the subset of anyhow's API that psfit uses: a
//! string-backed [`Error`], the [`Result`] alias, and the `anyhow!` /
//! `bail!` / `ensure!` macros.  Like the real anyhow, [`Error`] does NOT
//! implement `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! concrete error type) possible without overlapping impls.

use std::fmt;

/// A type-erased error: a rendered message (anyhow's dynamic error value,
/// reduced to its Display form — nothing in psfit downcasts).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> crate::Result<u32> {
            let v: u32 = "12".parse()?;
            Ok(v)
        }
        assert_eq!(inner().unwrap(), 12);

        fn bad() -> crate::Result<u32> {
            let v: u32 = "nope".parse()?;
            Ok(v)
        }
        assert!(bad().unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_build_messages() {
        let name = "x";
        let e = crate::anyhow!("missing `{name}` ({})", 7);
        assert_eq!(e.to_string(), "missing `x` (7)");

        fn guard(ok: bool) -> crate::Result<()> {
            crate::ensure!(ok, "flag was {ok}");
            Ok(())
        }
        assert!(guard(true).is_ok());
        assert_eq!(guard(false).unwrap_err().to_string(), "flag was false");

        fn never() -> crate::Result<()> {
            crate::bail!("nope");
        }
        assert_eq!(never().unwrap_err().to_string(), "nope");
    }
}

//! Offline stand-in for the `proptest` crate.
//!
//! The real proptest is unavailable in the offline build environment;
//! psfit's property tests (`rust/tests/proptests.rs`) run on the
//! self-contained seeded runner in `psfit::util::testkit` instead.  This
//! crate exists so the manifest can declare the dependency the test suite
//! is written against without reaching the network; it intentionally
//! exports nothing.

//! Offline stub of the `xla` (xla-rs 0.5.1) PJRT bindings.
//!
//! The real crate links the `xla_extension` C++ runtime, which is not
//! present in the offline build image.  This stub is API-compatible with
//! the subset `psfit::runtime` / `psfit::backend::xla` call, but every
//! entry point that would touch PJRT returns an error, starting with
//! [`PjRtClient::cpu`] — so the XLA ("GPU") backend fails fast at
//! construction with an actionable message while the native backend and
//! the rest of the system build and run unmodified.  Swapping the real
//! bindings back in is a one-line change in `rust/Cargo.toml`.

/// Error type matching how psfit consumes xla-rs errors: formatted with
/// `{:?}` into `anyhow` messages.
pub struct Error {
    what: &'static str,
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: XLA/PJRT runtime not available in this build (offline `xla` stub; \
             restore the real xla-rs dependency in rust/Cargo.toml to run GPU artifacts)",
            self.what
        )
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error { what })
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

/// A PJRT device handle.
pub struct PjRtDevice;

/// A device-resident buffer.
pub struct PjRtBuffer;

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

/// Parsed HLO module proto.
pub struct HloModuleProto;

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

/// A host-side literal (tensor value).
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_the_stub() {
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("PjRtClient::cpu"));
        assert!(err.contains("offline"));
        let err = format!("{:?}", HloModuleProto::from_text_file("x").unwrap_err());
        assert!(err.contains("from_text_file"));
    }
}
